//! Trace analysis: parse captured JSONL back into events, fold them into
//! a per-phase / per-strategy summary table, and export Chrome
//! `chrome://tracing` (about://tracing / Perfetto) format.

use anyhow::{anyhow, Context, Result};

use crate::draft::StrategyKind;
use crate::trace::{ConnEvent, Phase, RequestEvent, StepEvent, TraceEvent};
use crate::util::json::Json;

fn num(j: &Json, key: &str) -> u64 {
    j.get(key).and_then(|v| v.as_f64()).unwrap_or(0.0) as u64
}

/// Parse one JSONL trace line (as emitted by [`crate::trace::to_jsonl`]).
pub fn parse_line(line: &str) -> Result<TraceEvent> {
    let j = Json::parse(line).map_err(|e| anyhow!("bad trace line: {e}"))?;
    let ty = j.get("type").and_then(|t| t.as_str()).unwrap_or("");
    match ty {
        "step" => {
            let mut ev = StepEvent {
                t_us: num(&j, "t_us"),
                engine: num(&j, "engine"),
                step: num(&j, "step"),
                w: num(&j, "w") as u32,
                rows: num(&j, "rows") as u32,
                seqs: num(&j, "seqs") as u32,
                accepted: num(&j, "accepted") as u32,
                emitted: num(&j, "emitted") as u32,
                ..StepEvent::default()
            };
            if let Some(phases) = j.get("phases") {
                for p in Phase::ALL {
                    ev.phase_us[p.index()] = num(phases, p.label());
                }
            }
            if let Some(strategies) = j.get("strategies").and_then(|s| s.as_obj()) {
                for (label, stats) in strategies {
                    if let Some(kind) = StrategyKind::ALL.iter().find(|k| k.label() == label) {
                        ev.wins[kind.index()] = num(stats, "wins") as u32;
                        ev.accepted_by[kind.index()] = num(stats, "accepted") as u32;
                    }
                }
            }
            // tree provenance is optional: flat-mode lines omit it and the
            // fields default to 0
            if let Some(tree) = j.get("tree") {
                ev.tree_nodes = num(tree, "nodes") as u32;
                ev.tree_leaves = num(tree, "leaves") as u32;
                ev.tree_depth = num(tree, "depth") as u32;
            }
            Ok(TraceEvent::Step(ev))
        }
        "request" => Ok(TraceEvent::Request(RequestEvent {
            t_us: num(&j, "t_us"),
            queue_us: num(&j, "queue_us"),
            prefill_us: num(&j, "prefill_us"),
            ttft_us: num(&j, "ttft_us"),
            total_us: num(&j, "total_us"),
            tokens: num(&j, "tokens") as u32,
            calls: num(&j, "calls") as u32,
        })),
        "conn" => Ok(TraceEvent::Conn(ConnEvent {
            t_us: num(&j, "t_us"),
            read_us: num(&j, "read_us"),
            write_us: num(&j, "write_us"),
            bytes_in: num(&j, "bytes_in"),
            bytes_out: num(&j, "bytes_out"),
        })),
        other => Err(anyhow!("unknown trace event type '{other}'")),
    }
}

/// Parse a whole JSONL trace (blank lines are skipped).
pub fn parse_jsonl(text: &str) -> Result<Vec<TraceEvent>> {
    text.lines()
        .map(str::trim)
        .filter(|l| !l.is_empty())
        .enumerate()
        .map(|(i, l)| parse_line(l).with_context(|| format!("trace line {}", i + 1)))
        .collect()
}

/// Folded trace: per-phase totals, per-strategy provenance, and request
/// latency distributions.
#[derive(Debug, Default)]
pub struct TraceSummary {
    /// step events folded in
    pub steps: u64,
    /// request events folded in
    pub requests: u64,
    /// connection events folded in
    pub conns: u64,
    /// per-phase total microseconds, indexed by [`Phase::index`]
    pub phase_total_us: [u64; Phase::COUNT],
    /// events that contributed a non-zero span to each phase
    pub phase_hits: [u64; Phase::COUNT],
    /// per-strategy step wins, indexed by [`StrategyKind::index`]
    pub wins: [u64; StrategyKind::COUNT],
    /// per-strategy accepted draft tokens
    pub accepted_by: [u64; StrategyKind::COUNT],
    /// draft tokens accepted across all steps
    pub accepted: u64,
    /// tokens emitted across all steps
    pub emitted: u64,
    /// sorted submit→first-token latencies (µs), one per request
    pub ttft_us: Vec<u64>,
    /// sorted per-request mean inter-token latencies (µs)
    pub inter_token_us: Vec<u64>,
}

fn pct(sorted: &[u64], q: f64) -> u64 {
    if sorted.is_empty() {
        return 0;
    }
    let idx = ((q * sorted.len() as f64).ceil() as usize).clamp(1, sorted.len()) - 1;
    sorted[idx]
}

impl TraceSummary {
    /// Fold a batch of events into a summary.
    pub fn from_events(events: &[TraceEvent]) -> Self {
        let mut s = TraceSummary::default();
        for ev in events {
            match ev {
                TraceEvent::Step(e) => {
                    s.steps += 1;
                    for p in Phase::ALL {
                        let us = e.phase_us[p.index()];
                        s.phase_total_us[p.index()] += us;
                        if us > 0 {
                            s.phase_hits[p.index()] += 1;
                        }
                    }
                    for k in StrategyKind::ALL {
                        s.wins[k.index()] += e.wins[k.index()] as u64;
                        s.accepted_by[k.index()] += e.accepted_by[k.index()] as u64;
                    }
                    s.accepted += e.accepted as u64;
                    s.emitted += e.emitted as u64;
                }
                TraceEvent::Request(e) => {
                    s.requests += 1;
                    s.phase_total_us[Phase::QueueWait.index()] += e.queue_us;
                    s.phase_total_us[Phase::Prefill.index()] += e.prefill_us;
                    if e.queue_us > 0 {
                        s.phase_hits[Phase::QueueWait.index()] += 1;
                    }
                    if e.prefill_us > 0 {
                        s.phase_hits[Phase::Prefill.index()] += 1;
                    }
                    s.ttft_us.push(e.ttft_us);
                    if e.tokens > 1 {
                        s.inter_token_us
                            .push(e.total_us.saturating_sub(e.ttft_us) / (e.tokens as u64 - 1));
                    }
                }
                TraceEvent::Conn(e) => {
                    s.conns += 1;
                    s.phase_total_us[Phase::ConnRead.index()] += e.read_us;
                    s.phase_total_us[Phase::ConnWrite.index()] += e.write_us;
                    if e.read_us > 0 {
                        s.phase_hits[Phase::ConnRead.index()] += 1;
                    }
                    if e.write_us > 0 {
                        s.phase_hits[Phase::ConnWrite.index()] += 1;
                    }
                }
            }
        }
        s.ttft_us.sort_unstable();
        s.inter_token_us.sort_unstable();
        s
    }

    /// Parse + fold a captured JSONL trace.
    pub fn from_jsonl(text: &str) -> Result<Self> {
        Ok(Self::from_events(&parse_jsonl(text)?))
    }

    /// Per-phase totals as JSON (µs), for bench summaries: phase label →
    /// total microseconds (request-level phases included when present).
    pub fn phases_json(&self) -> Json {
        Json::Obj(
            Phase::ALL
                .iter()
                .map(|p| (p.label().to_string(), Json::Num(self.phase_total_us[p.index()] as f64)))
                .collect(),
        )
    }

    /// Render the human-readable breakdown: a per-phase table (total,
    /// share, mean per event) and a per-strategy provenance table.
    pub fn render_table(&self) -> String {
        let mut out = String::new();
        let step_total: u64 = Phase::ALL
            .iter()
            .filter(|p| p.is_step())
            .map(|p| self.phase_total_us[p.index()])
            .sum();
        out.push_str(&format!(
            "trace summary: {} steps, {} requests, {} tokens emitted ({} accepted drafts)\n\n",
            self.steps, self.requests, self.emitted, self.accepted
        ));
        out.push_str(&format!(
            "{:<12} {:>12} {:>8} {:>12} {:>8}\n",
            "phase", "total_us", "share", "mean_us", "events"
        ));
        for p in Phase::ALL {
            let total = self.phase_total_us[p.index()];
            let hits = self.phase_hits[p.index()];
            let share = if step_total > 0 && p.is_step() {
                format!("{:.1}%", 100.0 * total as f64 / step_total as f64)
            } else {
                "-".to_string()
            };
            let mean = if hits > 0 { total as f64 / hits as f64 } else { 0.0 };
            out.push_str(&format!(
                "{:<12} {:>12} {:>8} {:>12.1} {:>8}\n",
                p.label(),
                total,
                share,
                mean,
                hits
            ));
        }
        out.push_str(&format!(
            "\n{:<14} {:>8} {:>10} {:>12}\n",
            "strategy", "wins", "accepted", "acc/win"
        ));
        for k in StrategyKind::ALL {
            let wins = self.wins[k.index()];
            if wins == 0 && self.accepted_by[k.index()] == 0 {
                continue;
            }
            let per = if wins > 0 { self.accepted_by[k.index()] as f64 / wins as f64 } else { 0.0 };
            out.push_str(&format!(
                "{:<14} {:>8} {:>10} {:>12.2}\n",
                k.label(),
                wins,
                self.accepted_by[k.index()],
                per
            ));
        }
        if !self.ttft_us.is_empty() {
            out.push_str(&format!(
                "\nttft_us        p50 {:>8}  p99 {:>8}  ({} requests)\n",
                pct(&self.ttft_us, 0.5),
                pct(&self.ttft_us, 0.99),
                self.ttft_us.len()
            ));
        }
        if !self.inter_token_us.is_empty() {
            out.push_str(&format!(
                "inter_token_us p50 {:>8}  p99 {:>8}\n",
                pct(&self.inter_token_us, 0.5),
                pct(&self.inter_token_us, 0.99)
            ));
        }
        out
    }
}

/// Export events in Chrome trace format (a JSON array of complete `"X"`
/// events loadable in `chrome://tracing` or Perfetto). Each step's phases
/// are laid back-to-back ending at the step's timestamp; each request
/// becomes one span on the synthetic `requests` track (pid 9999).
pub fn chrome_trace(events: &[TraceEvent]) -> Json {
    let mut arr = Vec::new();
    let complete = |name: &str, cat: &str, ts: u64, dur: u64, pid: u64, tid: u64| {
        Json::obj(vec![
            ("name", Json::Str(name.to_string())),
            ("cat", Json::Str(cat.to_string())),
            ("ph", Json::Str("X".into())),
            ("ts", Json::Num(ts as f64)),
            ("dur", Json::Num(dur as f64)),
            ("pid", Json::Num(pid as f64)),
            ("tid", Json::Num(tid as f64)),
        ])
    };
    for ev in events {
        match ev {
            TraceEvent::Step(e) => {
                let total: u64 =
                    Phase::ALL.iter().filter(|p| p.is_step()).map(|p| e.phase_us[p.index()]).sum();
                let mut cursor = e.t_us.saturating_sub(total);
                for p in Phase::ALL {
                    if !p.is_step() {
                        continue;
                    }
                    let dur = e.phase_us[p.index()];
                    if dur == 0 {
                        continue;
                    }
                    arr.push(complete(p.label(), "step", cursor, dur, e.engine, e.w as u64));
                    cursor += dur;
                }
            }
            TraceEvent::Request(e) => {
                arr.push(complete(
                    "request",
                    "request",
                    e.t_us.saturating_sub(e.total_us),
                    e.total_us,
                    9999,
                    0,
                ));
            }
            TraceEvent::Conn(e) => {
                // read ends when the write begins; both land on the
                // synthetic `connections` track (pid 9998)
                let write_start = e.t_us.saturating_sub(e.write_us);
                if e.read_us > 0 {
                    arr.push(complete(
                        "conn-read",
                        "conn",
                        write_start.saturating_sub(e.read_us),
                        e.read_us,
                        9998,
                        0,
                    ));
                }
                if e.write_us > 0 {
                    arr.push(complete("conn-write", "conn", write_start, e.write_us, 9998, 0));
                }
            }
        }
    }
    Json::Arr(arr)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::trace::{to_jsonl, RequestEvent, StepEvent};

    fn step(t_us: u64) -> StepEvent {
        let mut e = StepEvent { t_us, step: 1, w: 4, rows: 3, seqs: 2, ..StepEvent::default() };
        e.phase_us[Phase::Draft.index()] = 10;
        e.phase_us[Phase::Verify.index()] = 80;
        e.phase_us[Phase::Commit.index()] = 10;
        e.wins[StrategyKind::ContextNgram.index()] = 2;
        e.accepted_by[StrategyKind::ContextNgram.index()] = 6;
        e.accepted = 6;
        e.emitted = 8;
        e
    }

    #[test]
    fn summary_folds_phases_and_strategies() {
        let events = vec![
            TraceEvent::Step(step(100)),
            TraceEvent::Step(step(200)),
            TraceEvent::Request(RequestEvent {
                t_us: 300,
                queue_us: 5,
                prefill_us: 50,
                ttft_us: 60,
                total_us: 260,
                tokens: 11,
                calls: 4,
            }),
        ];
        let s = TraceSummary::from_events(&events);
        assert_eq!(s.steps, 2);
        assert_eq!(s.requests, 1);
        assert_eq!(s.phase_total_us[Phase::Verify.index()], 160);
        assert_eq!(s.phase_total_us[Phase::QueueWait.index()], 5);
        assert_eq!(s.wins[StrategyKind::ContextNgram.index()], 4);
        assert_eq!(s.accepted, 12);
        assert_eq!(s.ttft_us, vec![60]);
        assert_eq!(s.inter_token_us, vec![20]);
        let table = s.render_table();
        assert!(table.contains("verify"));
        assert!(table.contains("context-ngram"));
        assert!(table.contains("ttft_us"));
    }

    #[test]
    fn summary_round_trips_through_jsonl() {
        let events =
            vec![TraceEvent::Step(step(100)), TraceEvent::Request(RequestEvent::default())];
        let text = to_jsonl(&events);
        let s = TraceSummary::from_jsonl(&text).unwrap();
        assert_eq!(s.steps, 1);
        assert_eq!(s.requests, 1);
        assert_eq!(s.phase_total_us[Phase::Verify.index()], 80);
    }

    #[test]
    fn chrome_export_lays_phases_back_to_back() {
        let j = chrome_trace(&[TraceEvent::Step(step(1000))]);
        let arr = j.as_arr().unwrap();
        assert_eq!(arr.len(), 3); // draft, verify, commit (judge/pack are 0)
        let ts: Vec<u64> =
            arr.iter().map(|e| e.get("ts").and_then(|t| t.as_f64()).unwrap() as u64).collect();
        let durs: Vec<u64> =
            arr.iter().map(|e| e.get("dur").and_then(|t| t.as_f64()).unwrap() as u64).collect();
        assert_eq!(ts[0], 1000 - 100);
        assert_eq!(ts[1], ts[0] + durs[0]);
        assert_eq!(ts[2], ts[1] + durs[1]);
        assert_eq!(ts[2] + durs[2], 1000);
        let bad = chrome_trace(&[]);
        assert_eq!(bad.as_arr().unwrap().len(), 0);
    }

    #[test]
    fn conn_events_fold_and_round_trip() {
        let events = vec![TraceEvent::Conn(ConnEvent {
            t_us: 500,
            read_us: 40,
            write_us: 60,
            bytes_in: 120,
            bytes_out: 333,
        })];
        let s = TraceSummary::from_jsonl(&to_jsonl(&events)).unwrap();
        assert_eq!(s.conns, 1);
        assert_eq!(s.phase_total_us[Phase::ConnRead.index()], 40);
        assert_eq!(s.phase_total_us[Phase::ConnWrite.index()], 60);
        // conn phases never dilute the step share column
        assert!(s.render_table().contains("conn-read"));
        let j = chrome_trace(&events);
        let arr = j.as_arr().unwrap();
        assert_eq!(arr.len(), 2);
        assert_eq!(arr[0].get("ts").and_then(|t| t.as_f64()).unwrap() as u64, 400);
        assert_eq!(arr[1].get("ts").and_then(|t| t.as_f64()).unwrap() as u64, 440);
    }

    #[test]
    fn parse_rejects_unknown_event_type() {
        assert!(parse_line("{\"type\":\"mystery\"}").is_err());
        assert!(parse_jsonl("{\"type\":\"step\"}\n\n{\"type\":\"request\"}").is_ok());
    }
}
