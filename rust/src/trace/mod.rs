//! Decode-path flight recorder: per-engine seqlock ring buffers of step
//! events plus a process-wide hub that merges them into an exportable
//! trace.
//!
//! The paper's argument is a cost-accounting one — learning-free drafts
//! win because drafting is negligible next to verification — so the
//! recorder's job is to say where each decode step's wall-clock actually
//! goes. Every packed step logs a [`StepEvent`] carrying per-phase
//! durations ([`Phase`]: draft propose, batch pack, model verify,
//! acceptance judge, KV commit) plus per-row provenance (which
//! [`StrategyKind`] won, how many tokens it got accepted); every request
//! logs admission → first-token → completion spans as a
//! [`RequestEvent`].
//!
//! Tracing is zero-cost when idle: a disabled recorder is one relaxed
//! atomic load and a branch (`Instant::now` is never called), an enabled
//! one is a handful of clock reads and a seqlock ring write — no locks,
//! no allocation, no syscalls on the step path. `rust/tests/trace.rs`
//! pins both properties: traced output is byte-identical to untraced and
//! cost-model throughput is unchanged.

use std::cell::UnsafeCell;
use std::collections::VecDeque;
use std::sync::atomic::{fence, AtomicBool, AtomicU64, Ordering};
use std::sync::{Arc, Mutex};
use std::time::Instant;

use crate::draft::StrategyKind;
use crate::metrics::Metrics;
use crate::util::json::Json;

pub mod report;

/// Default per-engine ring capacity (events); old events are overwritten.
pub const DEFAULT_RING_CAPACITY: usize = 4096;

/// Decode-path phase taxonomy. `QueueWait` and `Prefill` are request-level
/// spans (admission queue dwell, prompt prefill); `ConnRead` and
/// `ConnWrite` are connection-level spans stamped by the reactor
/// front-end (accept → request parsed, response start → flushed); the
/// rest are the packed step lifecycle in
/// [`crate::engine::BatchedEngine`] order.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Phase {
    /// submit → dequeue dwell in the admission queue
    QueueWait,
    /// prompt prefill on admission (one full-context model call)
    Prefill,
    /// draft proposal: strategy reset/propose + row padding
    Draft,
    /// batch pack: arena assembly + KV views + packed-block build
    Pack,
    /// the packed model verification call
    Verify,
    /// acceptance judging (longest agreeing row vs greedy column)
    Judge,
    /// KV tail commit (including copy-on-write page work)
    Commit,
    /// reactor: connection accept → request fully read and parsed
    ConnRead,
    /// reactor: response write start → fully flushed
    ConnWrite,
}

impl Phase {
    /// Number of phases (sizes array-backed per-phase statistics).
    pub const COUNT: usize = 9;

    /// Every phase, in `index()` order.
    pub const ALL: [Phase; Self::COUNT] = [
        Phase::QueueWait,
        Phase::Prefill,
        Phase::Draft,
        Phase::Pack,
        Phase::Verify,
        Phase::Judge,
        Phase::Commit,
        Phase::ConnRead,
        Phase::ConnWrite,
    ];

    /// Dense index into `ALL` (declaration order == discriminant).
    pub fn index(&self) -> usize {
        *self as usize
    }

    /// Whether this phase is part of the packed step lifecycle (the
    /// phases a [`StepEvent`] carries), as opposed to the request-level
    /// (`QueueWait`/`Prefill`) and connection-level
    /// (`ConnRead`/`ConnWrite`) spans.
    pub fn is_step(&self) -> bool {
        matches!(self, Phase::Draft | Phase::Pack | Phase::Verify | Phase::Judge | Phase::Commit)
    }

    /// Stable label used in metrics, JSONL and report tables.
    pub fn label(&self) -> &'static str {
        match self {
            Phase::QueueWait => "queue-wait",
            Phase::Prefill => "prefill",
            Phase::Draft => "draft",
            Phase::Pack => "pack",
            Phase::Verify => "verify",
            Phase::Judge => "judge",
            Phase::Commit => "commit",
            Phase::ConnRead => "conn-read",
            Phase::ConnWrite => "conn-write",
        }
    }
}

/// One packed decode step's record: fixed-size and `Copy` so the seqlock
/// ring can publish it with plain stores and readers can detect torn
/// copies by sequence number alone.
#[derive(Debug, Clone, Copy, Default)]
pub struct StepEvent {
    /// microseconds since the owning [`TraceHub`]'s epoch, stamped when
    /// the step's group finished
    pub t_us: u64,
    /// owning engine's stable spawn ordinal
    pub engine: u64,
    /// engine-local step counter
    pub step: u64,
    /// draft depth (tokens per row) of this packed group
    pub w: u32,
    /// total draft rows packed across the group's sequences
    pub rows: u32,
    /// sequences in the packed group
    pub seqs: u32,
    /// per-phase wall-clock microseconds, indexed by [`Phase::index`]
    /// (`QueueWait`/`Prefill` stay 0 — those are request-level spans)
    pub phase_us: [u64; Phase::COUNT],
    /// draft tokens accepted across the group this step
    pub accepted: u32,
    /// tokens emitted across the group this step (accepted + greedy)
    pub emitted: u32,
    /// per-strategy step wins this group, indexed by
    /// [`StrategyKind::index`]
    pub wins: [u32; StrategyKind::COUNT],
    /// per-strategy accepted draft tokens this group, same indexing
    pub accepted_by: [u32; StrategyKind::COUNT],
    /// tree mode: total trie nodes verified across the group (0 = the
    /// group ran flat rows; `rows` then carries the row count)
    pub tree_nodes: u32,
    /// tree mode: total leaves (distinct root-to-leaf candidate paths)
    pub tree_leaves: u32,
    /// tree mode: deepest node depth across the group's trees
    pub tree_depth: u32,
}

/// One request's latency record: admission → first token → completion.
#[derive(Debug, Clone, Copy, Default)]
pub struct RequestEvent {
    /// microseconds since the hub epoch, stamped at completion
    pub t_us: u64,
    /// submit → dequeue dwell in the scheduler queue (µs)
    pub queue_us: u64,
    /// prompt prefill span (µs)
    pub prefill_us: u64,
    /// submit → first emitted token (µs)
    pub ttft_us: u64,
    /// submit → reply (µs)
    pub total_us: u64,
    /// tokens generated
    pub tokens: u32,
    /// verification calls spent
    pub calls: u32,
}

/// One served connection's span record, stamped by the reactor
/// front-end when a response finishes flushing.
#[derive(Debug, Clone, Copy, Default)]
pub struct ConnEvent {
    /// microseconds since the hub epoch, stamped at close
    pub t_us: u64,
    /// accept → request fully read and parsed (µs; the ConnRead phase)
    pub read_us: u64,
    /// response write start → fully flushed (µs; the ConnWrite phase)
    pub write_us: u64,
    /// request bytes received
    pub bytes_in: u64,
    /// response bytes sent
    pub bytes_out: u64,
}

/// A merged trace entry: a packed step, a completed request, or a served
/// connection.
#[derive(Debug, Clone, Copy)]
pub enum TraceEvent {
    /// one packed decode step
    Step(StepEvent),
    /// one completed request
    Request(RequestEvent),
    /// one served connection (reactor front-end)
    Conn(ConnEvent),
}

impl TraceEvent {
    /// Event timestamp (µs since the hub epoch) for merge ordering.
    pub fn t_us(&self) -> u64 {
        match self {
            TraceEvent::Step(e) => e.t_us,
            TraceEvent::Request(e) => e.t_us,
            TraceEvent::Conn(e) => e.t_us,
        }
    }
}

/// One seqlock slot: version counter + the event payload. The counter is
/// `2*h + 1` while version `h` is being written and `2*(h + 1)` once it
/// is published, so a reader knows both "torn" and "which version".
struct Slot {
    seq: AtomicU64,
    data: UnsafeCell<StepEvent>,
}

/// Fixed-capacity single-writer seqlock ring of [`StepEvent`]s.
///
/// The owning engine thread is the only writer; any thread may snapshot.
/// Writers never block or allocate; readers copy optimistically and
/// retry (or skip) slots whose sequence number moved underneath them.
pub struct StepRing {
    slots: Box<[Slot]>,
    head: AtomicU64,
}

// SAFETY: `data` is only written by the single writer thread between the
// odd/even seq stores; readers access it exclusively through
// `read_volatile` and discard any copy whose seq check fails, so a torn
// read is detected, never interpreted.
unsafe impl Sync for StepRing {}

impl std::fmt::Debug for StepRing {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        f.debug_struct("StepRing")
            .field("capacity", &self.slots.len())
            .field("head", &self.head.load(Ordering::Relaxed))
            .finish()
    }
}

impl StepRing {
    /// A ring holding the last `capacity` events (capacity is clamped to
    /// at least 1).
    pub fn new(capacity: usize) -> Self {
        let slots = (0..capacity.max(1))
            .map(|_| Slot { seq: AtomicU64::new(0), data: UnsafeCell::new(StepEvent::default()) })
            .collect::<Vec<_>>()
            .into_boxed_slice();
        StepRing { slots, head: AtomicU64::new(0) }
    }

    /// Events ever pushed (not capped at capacity).
    pub fn pushed(&self) -> u64 {
        self.head.load(Ordering::Acquire)
    }

    /// Publish one event. Single-writer: only the owning engine thread
    /// may call this.
    pub fn push(&self, ev: StepEvent) {
        let h = self.head.load(Ordering::Relaxed);
        let slot = &self.slots[h as usize % self.slots.len()];
        slot.seq.store(2 * h + 1, Ordering::Relaxed);
        fence(Ordering::Release);
        // SAFETY: single writer (see struct docs); readers detect this
        // in-flight write via the odd seq and discard their copy.
        unsafe { *slot.data.get() = ev };
        slot.seq.store(2 * (h + 1), Ordering::Release);
        self.head.store(h + 1, Ordering::Release);
    }

    /// Copy out up to the last `n` events, oldest first. Slots the writer
    /// overtakes mid-copy are skipped rather than returned torn.
    pub fn snapshot(&self, n: usize) -> Vec<StepEvent> {
        let head = self.head.load(Ordering::Acquire);
        let stored = head.min(self.slots.len() as u64);
        let take = (n as u64).min(stored);
        let mut out = Vec::with_capacity(take as usize);
        for h in (head - take)..head {
            let slot = &self.slots[h as usize % self.slots.len()];
            let want = 2 * (h + 1);
            for _attempt in 0..4 {
                let s1 = slot.seq.load(Ordering::Acquire);
                if s1 > want {
                    break; // writer lapped this slot: version h is gone
                }
                // SAFETY: volatile copy of Copy data; validity is
                // established by the seq re-check below, a torn copy is
                // discarded.
                let ev = unsafe { std::ptr::read_volatile(slot.data.get()) };
                fence(Ordering::Acquire);
                let s2 = slot.seq.load(Ordering::Relaxed);
                if s1 == s2 && s1 == want {
                    out.push(ev);
                    break;
                }
            }
        }
        out
    }
}

/// One engine's flight recorder: an enabled flag shared with the hub, the
/// hub's epoch for aligned timestamps, and this engine's private
/// [`StepRing`]. Cloned `Arc`s hand the reader side to the hub while the
/// engine thread keeps the (single) writer side.
#[derive(Debug)]
pub struct FlightRecorder {
    engine: u64,
    enabled: Arc<AtomicBool>,
    epoch: Instant,
    ring: StepRing,
    metrics: Option<Arc<Metrics>>,
}

impl FlightRecorder {
    /// A standalone recorder (not attached to a hub) — handy for benches
    /// and tests that trace one engine directly.
    pub fn standalone(engine: u64, capacity: usize) -> Arc<FlightRecorder> {
        Arc::new(FlightRecorder {
            engine,
            enabled: Arc::new(AtomicBool::new(true)),
            epoch: Instant::now(),
            ring: StepRing::new(capacity),
            metrics: None,
        })
    }

    /// Whether recording is on. This is the whole cost of a disabled
    /// recorder: one relaxed load.
    #[inline]
    pub fn enabled(&self) -> bool {
        self.enabled.load(Ordering::Relaxed)
    }

    /// Owning engine's id (stamped into every event).
    pub fn engine_id(&self) -> u64 {
        self.engine
    }

    /// Microseconds since the hub epoch.
    pub fn now_us(&self) -> u64 {
        self.epoch.elapsed().as_micros() as u64
    }

    /// Record one step: stamps engine id + timestamp, publishes to the
    /// ring, and feeds the per-phase latency histograms when the recorder
    /// is wired to [`Metrics`]. No-op when disabled.
    pub fn record_step(&self, mut ev: StepEvent) {
        if !self.enabled() {
            return;
        }
        ev.engine = self.engine;
        ev.t_us = self.now_us();
        self.ring.push(ev);
        if let Some(m) = &self.metrics {
            for p in Phase::ALL {
                let us = ev.phase_us[p.index()];
                if us > 0 {
                    m.phase_latency[p.index()].observe(std::time::Duration::from_micros(us));
                }
            }
        }
    }

    /// Copy out up to the last `n` step events, oldest first.
    pub fn snapshot(&self, n: usize) -> Vec<StepEvent> {
        self.ring.snapshot(n)
    }

    /// Steps ever recorded by this engine.
    pub fn steps_recorded(&self) -> u64 {
        self.ring.pushed()
    }
}

/// Process-wide trace hub: owns the epoch, the enabled flag, the bounded
/// request-event log, and the reader side of every engine's recorder.
/// The scheduler creates one per serving stack; `GET /trace` and
/// `GET /stats` read through it.
#[derive(Debug)]
pub struct TraceHub {
    enabled: Arc<AtomicBool>,
    capacity: usize,
    epoch: Instant,
    engines: Mutex<Vec<Arc<FlightRecorder>>>,
    requests: Mutex<VecDeque<RequestEvent>>,
    conns: Mutex<VecDeque<ConnEvent>>,
    metrics: Option<Arc<Metrics>>,
}

impl TraceHub {
    /// An enabled hub whose engine rings hold `capacity` events each.
    pub fn new(capacity: usize) -> Self {
        TraceHub {
            enabled: Arc::new(AtomicBool::new(true)),
            capacity: capacity.max(1),
            epoch: Instant::now(),
            engines: Mutex::new(Vec::new()),
            requests: Mutex::new(VecDeque::new()),
            conns: Mutex::new(VecDeque::new()),
            metrics: None,
        }
    }

    /// An enabled hub that also feeds the ttft / inter-token / per-phase
    /// latency histograms on `metrics`.
    pub fn with_metrics(capacity: usize, metrics: Arc<Metrics>) -> Self {
        let mut hub = Self::new(capacity);
        hub.metrics = Some(metrics);
        hub
    }

    /// A disabled hub: recorders handed out record nothing until
    /// [`TraceHub::set_enabled`] flips it on.
    pub fn disabled(capacity: usize) -> Self {
        let hub = Self::new(capacity);
        hub.enabled.store(false, Ordering::Relaxed);
        hub
    }

    /// Flip recording on/off for every recorder minted by this hub.
    pub fn set_enabled(&self, on: bool) {
        self.enabled.store(on, Ordering::Relaxed);
    }

    /// Whether recording is currently on.
    pub fn enabled(&self) -> bool {
        self.enabled.load(Ordering::Relaxed)
    }

    /// Microseconds since the hub epoch.
    pub fn now_us(&self) -> u64 {
        self.epoch.elapsed().as_micros() as u64
    }

    /// Mint (and register) engine `id`'s recorder. The engine thread
    /// keeps the returned `Arc` as the ring's single writer; the hub
    /// keeps a clone for snapshots. Re-registering an id (engine replaced
    /// after a step error) supersedes the old recorder.
    pub fn recorder_for_engine(&self, id: u64) -> Arc<FlightRecorder> {
        let rec = Arc::new(FlightRecorder {
            engine: id,
            enabled: Arc::clone(&self.enabled),
            epoch: self.epoch,
            ring: StepRing::new(self.capacity),
            metrics: self.metrics.clone(),
        });
        let mut engines = self.engines.lock().unwrap();
        engines.retain(|r| r.engine != id);
        engines.push(Arc::clone(&rec));
        rec
    }

    /// Record one completed request's spans: appends a [`RequestEvent`]
    /// (bounded by the ring capacity) and feeds the ttft / inter-token /
    /// queue-wait / prefill histograms when wired to metrics. No-op when
    /// the hub is disabled.
    pub fn record_request(&self, mut ev: RequestEvent) {
        if !self.enabled() {
            return;
        }
        ev.t_us = self.now_us();
        if let Some(m) = &self.metrics {
            let us = std::time::Duration::from_micros;
            m.ttft.observe(us(ev.ttft_us));
            if ev.tokens > 1 {
                let inter = (ev.total_us.saturating_sub(ev.ttft_us)) / (ev.tokens as u64 - 1);
                m.inter_token.observe(us(inter));
            }
            if ev.queue_us > 0 {
                m.phase_latency[Phase::QueueWait.index()].observe(us(ev.queue_us));
            }
            if ev.prefill_us > 0 {
                m.phase_latency[Phase::Prefill.index()].observe(us(ev.prefill_us));
            }
        }
        let mut reqs = self.requests.lock().unwrap();
        if reqs.len() >= self.capacity {
            reqs.pop_front();
        }
        reqs.push_back(ev);
    }

    /// Record one served connection's spans (reactor front-end): appends
    /// a [`ConnEvent`] (bounded by the ring capacity) and feeds the
    /// conn-read / conn-write phase histograms when wired to metrics.
    /// No-op when the hub is disabled.
    pub fn record_conn(&self, mut ev: ConnEvent) {
        if !self.enabled() {
            return;
        }
        ev.t_us = self.now_us();
        if let Some(m) = &self.metrics {
            let us = std::time::Duration::from_micros;
            if ev.read_us > 0 {
                m.phase_latency[Phase::ConnRead.index()].observe(us(ev.read_us));
            }
            if ev.write_us > 0 {
                m.phase_latency[Phase::ConnWrite.index()].observe(us(ev.write_us));
            }
        }
        let mut conns = self.conns.lock().unwrap();
        if conns.len() >= self.capacity {
            conns.pop_front();
        }
        conns.push_back(ev);
    }

    /// Merge the last `n` events across every engine ring, the request
    /// log, and the connection log, ordered by timestamp (oldest first).
    pub fn recent(&self, n: usize) -> Vec<TraceEvent> {
        let mut out: Vec<TraceEvent> = Vec::new();
        for rec in self.engines.lock().unwrap().iter() {
            out.extend(rec.snapshot(n).into_iter().map(TraceEvent::Step));
        }
        out.extend(self.requests.lock().unwrap().iter().copied().map(TraceEvent::Request));
        out.extend(self.conns.lock().unwrap().iter().copied().map(TraceEvent::Conn));
        out.sort_by_key(|e| e.t_us());
        if out.len() > n {
            out.drain(..out.len() - n);
        }
        out
    }

    /// Total steps recorded across every registered engine.
    pub fn steps_recorded(&self) -> u64 {
        self.engines.lock().unwrap().iter().map(|r| r.steps_recorded()).sum()
    }
}

/// A step event's JSONL object (`"type":"step"`). Strategy provenance
/// only lists kinds that actually won a sequence this step, keeping lines
/// compact.
pub fn step_to_json(ev: &StepEvent) -> Json {
    let phases = Phase::ALL
        .iter()
        .filter(|p| p.is_step())
        .map(|p| (p.label().to_string(), Json::Num(ev.phase_us[p.index()] as f64)))
        .collect();
    let strategies = StrategyKind::ALL
        .iter()
        .filter(|k| ev.wins[k.index()] > 0)
        .map(|k| {
            (
                k.label().to_string(),
                Json::obj(vec![
                    ("wins", Json::Num(ev.wins[k.index()] as f64)),
                    ("accepted", Json::Num(ev.accepted_by[k.index()] as f64)),
                ]),
            )
        })
        .collect();
    let mut fields = vec![
        ("type", Json::Str("step".into())),
        ("t_us", Json::Num(ev.t_us as f64)),
        ("engine", Json::Num(ev.engine as f64)),
        ("step", Json::Num(ev.step as f64)),
        ("w", Json::Num(ev.w as f64)),
        ("rows", Json::Num(ev.rows as f64)),
        ("seqs", Json::Num(ev.seqs as f64)),
        ("accepted", Json::Num(ev.accepted as f64)),
        ("emitted", Json::Num(ev.emitted as f64)),
        ("phases", Json::Obj(phases)),
        ("strategies", Json::Obj(strategies)),
    ];
    // tree-shape provenance only on tree-mode steps, keeping flat-mode
    // lines unchanged
    if ev.tree_nodes > 0 {
        fields.push((
            "tree",
            Json::obj(vec![
                ("nodes", Json::Num(ev.tree_nodes as f64)),
                ("leaves", Json::Num(ev.tree_leaves as f64)),
                ("depth", Json::Num(ev.tree_depth as f64)),
            ]),
        ));
    }
    Json::obj(fields)
}

/// A request event's JSONL object (`"type":"request"`).
pub fn request_to_json(ev: &RequestEvent) -> Json {
    Json::obj(vec![
        ("type", Json::Str("request".into())),
        ("t_us", Json::Num(ev.t_us as f64)),
        ("queue_us", Json::Num(ev.queue_us as f64)),
        ("prefill_us", Json::Num(ev.prefill_us as f64)),
        ("ttft_us", Json::Num(ev.ttft_us as f64)),
        ("total_us", Json::Num(ev.total_us as f64)),
        ("tokens", Json::Num(ev.tokens as f64)),
        ("calls", Json::Num(ev.calls as f64)),
    ])
}

/// A connection event's JSONL object (`"type":"conn"`).
pub fn conn_to_json(ev: &ConnEvent) -> Json {
    Json::obj(vec![
        ("type", Json::Str("conn".into())),
        ("t_us", Json::Num(ev.t_us as f64)),
        ("read_us", Json::Num(ev.read_us as f64)),
        ("write_us", Json::Num(ev.write_us as f64)),
        ("bytes_in", Json::Num(ev.bytes_in as f64)),
        ("bytes_out", Json::Num(ev.bytes_out as f64)),
    ])
}

/// Serialize events as JSONL (one compact JSON object per line).
pub fn to_jsonl(events: &[TraceEvent]) -> String {
    let mut s = String::new();
    for ev in events {
        let j = match ev {
            TraceEvent::Step(e) => step_to_json(e),
            TraceEvent::Request(e) => request_to_json(e),
            TraceEvent::Conn(e) => conn_to_json(e),
        };
        s.push_str(&j.to_string());
        s.push('\n');
    }
    s
}

/// Per-step phase stopwatch. Built disabled (`enabled = false`) it never
/// reads the clock — `lap` is a branch on a `None` — which is what makes
/// tracing zero-cost when the recorder is off.
#[derive(Debug)]
pub struct PhaseTimer {
    last: Option<Instant>,
    /// accumulated per-phase microseconds, indexed by [`Phase::index`]
    pub us: [u64; Phase::COUNT],
}

impl PhaseTimer {
    /// A stopwatch; `enabled = false` makes every call a no-op.
    pub fn new(enabled: bool) -> Self {
        PhaseTimer { last: enabled.then(Instant::now), us: [0; Phase::COUNT] }
    }

    /// Whether this timer is live.
    #[inline]
    pub fn enabled(&self) -> bool {
        self.last.is_some()
    }

    /// Attribute the time since the previous lap to `phase` and restart
    /// the lap clock. Laps may interleave; per-phase time accumulates.
    #[inline]
    pub fn lap(&mut self, phase: Phase) {
        if let Some(prev) = self.last {
            let now = Instant::now();
            self.us[phase.index()] += now.duration_since(prev).as_micros() as u64;
            self.last = Some(now);
        }
    }

    /// Restart the lap clock without attributing the elapsed gap to any
    /// phase (for untimed sections between phases).
    #[inline]
    pub fn skip(&mut self) {
        if self.last.is_some() {
            self.last = Some(Instant::now());
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn ev(step: u64) -> StepEvent {
        StepEvent { step, w: 4, rows: 3, seqs: 2, ..StepEvent::default() }
    }

    #[test]
    fn ring_snapshot_returns_last_n_in_order() {
        let ring = StepRing::new(8);
        for i in 0..5 {
            ring.push(ev(i));
        }
        let got = ring.snapshot(3);
        assert_eq!(got.iter().map(|e| e.step).collect::<Vec<_>>(), vec![2, 3, 4]);
        assert_eq!(ring.pushed(), 5);
    }

    #[test]
    fn ring_wraps_and_keeps_newest() {
        let ring = StepRing::new(4);
        for i in 0..10 {
            ring.push(ev(i));
        }
        let got = ring.snapshot(100);
        assert_eq!(got.iter().map(|e| e.step).collect::<Vec<_>>(), vec![6, 7, 8, 9]);
    }

    #[test]
    fn disabled_recorder_records_nothing() {
        let hub = TraceHub::disabled(16);
        let rec = hub.recorder_for_engine(0);
        rec.record_step(ev(1));
        hub.record_request(RequestEvent::default());
        assert_eq!(rec.steps_recorded(), 0);
        assert!(hub.recent(10).is_empty());
        hub.set_enabled(true);
        rec.record_step(ev(2));
        assert_eq!(rec.steps_recorded(), 1);
    }

    #[test]
    fn hub_merges_steps_and_requests_by_time() {
        let hub = TraceHub::new(16);
        let r0 = hub.recorder_for_engine(0);
        let r1 = hub.recorder_for_engine(1);
        r0.record_step(ev(1));
        hub.record_request(RequestEvent {
            ttft_us: 100,
            total_us: 300,
            tokens: 5,
            calls: 2,
            ..RequestEvent::default()
        });
        r1.record_step(ev(2));
        let events = hub.recent(10);
        assert_eq!(events.len(), 3);
        let ts: Vec<u64> = events.iter().map(|e| e.t_us()).collect();
        let mut sorted = ts.clone();
        sorted.sort();
        assert_eq!(ts, sorted);
        assert_eq!(events.iter().filter(|e| matches!(e, TraceEvent::Request(_))).count(), 1);
    }

    #[test]
    fn jsonl_round_trips_through_parser() {
        let hub = TraceHub::new(16);
        let rec = hub.recorder_for_engine(3);
        let mut e = ev(7);
        e.phase_us[Phase::Verify.index()] = 120;
        e.wins[StrategyKind::ContextNgram.index()] = 2;
        e.accepted_by[StrategyKind::ContextNgram.index()] = 5;
        e.accepted = 5;
        e.emitted = 7;
        rec.record_step(e);
        hub.record_request(RequestEvent {
            queue_us: 10,
            prefill_us: 20,
            ttft_us: 30,
            total_us: 90,
            tokens: 4,
            calls: 2,
            ..RequestEvent::default()
        });
        let text = to_jsonl(&hub.recent(10));
        assert_eq!(text.lines().count(), 2);
        for line in text.lines() {
            let j = Json::parse(line).expect("valid json line");
            assert!(j.get("type").and_then(|t| t.as_str()).is_some());
        }
        let parsed = report::parse_jsonl(&text).unwrap();
        assert_eq!(parsed.len(), 2);
        match &parsed[0] {
            TraceEvent::Step(s) => {
                assert_eq!(s.engine, 3);
                assert_eq!(s.phase_us[Phase::Verify.index()], 120);
                assert_eq!(s.wins[StrategyKind::ContextNgram.index()], 2);
                assert_eq!(s.accepted, 5);
            }
            other => panic!("expected step first, got {other:?}"),
        }
    }

    #[test]
    fn tree_fields_round_trip_and_stay_off_flat_lines() {
        // flat-mode events carry no "tree" object
        let flat = step_to_json(&ev(1)).to_string();
        assert!(!flat.contains("\"tree\""));
        // tree-mode events round-trip their shape provenance
        let mut e = ev(2);
        e.tree_nodes = 17;
        e.tree_leaves = 5;
        e.tree_depth = 4;
        let line = step_to_json(&e).to_string();
        assert!(line.contains("\"tree\""));
        match report::parse_line(&line).unwrap() {
            TraceEvent::Step(s) => {
                assert_eq!(s.tree_nodes, 17);
                assert_eq!(s.tree_leaves, 5);
                assert_eq!(s.tree_depth, 4);
            }
            other => panic!("expected step, got {other:?}"),
        }
    }

    #[test]
    fn phase_timer_disabled_is_inert() {
        let mut t = PhaseTimer::new(false);
        t.lap(Phase::Draft);
        t.skip();
        assert!(!t.enabled());
        assert_eq!(t.us, [0; Phase::COUNT]);
    }

    #[test]
    fn phase_timer_accumulates_laps() {
        let mut t = PhaseTimer::new(true);
        std::thread::sleep(std::time::Duration::from_millis(2));
        t.lap(Phase::Draft);
        std::thread::sleep(std::time::Duration::from_millis(2));
        t.lap(Phase::Verify);
        assert!(t.us[Phase::Draft.index()] >= 1_000);
        assert!(t.us[Phase::Verify.index()] >= 1_000);
        assert_eq!(t.us[Phase::Commit.index()], 0);
    }

    #[test]
    fn concurrent_reader_never_sees_torn_events() {
        use std::sync::atomic::AtomicBool;
        let ring = Arc::new(StepRing::new(32));
        let stop = Arc::new(AtomicBool::new(false));
        let writer = {
            let ring = Arc::clone(&ring);
            let stop = Arc::clone(&stop);
            std::thread::spawn(move || {
                let mut i = 0u64;
                while !stop.load(Ordering::Relaxed) {
                    // every field of version i is i, so a mixed copy is
                    // detectable
                    let mut e = StepEvent { step: i, t_us: i, engine: i, ..Default::default() };
                    e.w = i as u32;
                    e.rows = i as u32;
                    ring.push(e);
                    i += 1;
                }
            })
        };
        for _ in 0..2_000 {
            for e in ring.snapshot(32) {
                assert_eq!(e.step, e.t_us);
                assert_eq!(e.step, e.engine);
                assert_eq!(e.w, e.rows);
                assert_eq!(e.step as u32, e.w);
            }
        }
        stop.store(true, Ordering::Relaxed);
        writer.join().unwrap();
    }
}
