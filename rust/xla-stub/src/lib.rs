//! Type-level stub of the `xla` crate API surface used by
//! `rust/src/runtime/pjrt.rs`.
//!
//! The real `xla` crate ships with the offline accelerator toolchain
//! image and links the PJRT C API — it cannot be vendored here. Without
//! ANY `xla` crate, `--features pjrt` does not even typecheck, so the
//! feature gate rots silently (dead `cfg` blocks, drifted signatures).
//! This stub keeps the gate honest: `cargo check --features pjrt` (the
//! CI feature-matrix job) compiles the whole PJRT backend against these
//! signatures, while every entry point FAILS AT RUNTIME with an explicit
//! error — never a silent wrong result. To actually execute on PJRT,
//! repoint the root `Cargo.toml`'s `xla` path dependency at the
//! toolchain's real crate.
//!
//! Only the surface the backend uses is modelled; extending the backend
//! to a new `xla` API means extending this stub in the same PR, which is
//! exactly the drift-check the feature-matrix job exists to enforce.

use anyhow::{bail, Result};

/// How every stub entry point fails.
const STUB_MSG: &str =
    "xla stub: the PJRT runtime is not linked (repoint the `xla` path dependency in Cargo.toml \
     at the offline toolchain's real crate)";

/// Parsed HLO module proto (stub: never constructable).
pub struct HloModuleProto;

impl HloModuleProto {
    /// Parse an HLO text file (stub: always fails).
    pub fn from_text_file(_path: &str) -> Result<Self> {
        bail!(STUB_MSG)
    }
}

/// An XLA computation handle (stub).
pub struct XlaComputation;

impl XlaComputation {
    /// Wrap a parsed proto (stub: constructable, but nothing accepts it
    /// at runtime — compilation fails first).
    pub fn from_proto(_proto: &HloModuleProto) -> Self {
        XlaComputation
    }
}

/// A PJRT device handle (stub).
pub struct PjRtDevice;

/// A PJRT client (stub: never constructable).
pub struct PjRtClient;

impl PjRtClient {
    /// Create the CPU client (stub: always fails — this is the first
    /// call the backend makes, so the failure surfaces at load time).
    pub fn cpu() -> Result<Self> {
        bail!(STUB_MSG)
    }

    /// Compile a computation (stub: always fails).
    pub fn compile(&self, _comp: &XlaComputation) -> Result<PjRtLoadedExecutable> {
        bail!(STUB_MSG)
    }

    /// Upload a host buffer to the device (stub: always fails).
    pub fn buffer_from_host_buffer<T: Copy>(
        &self,
        _data: &[T],
        _dims: &[usize],
        _device: Option<&PjRtDevice>,
    ) -> Result<PjRtBuffer> {
        bail!(STUB_MSG)
    }
}

/// A compiled, loaded executable (stub).
pub struct PjRtLoadedExecutable;

impl PjRtLoadedExecutable {
    /// Execute with device-buffer arguments (stub: always fails).
    pub fn execute_b(&self, _args: &[&PjRtBuffer]) -> Result<Vec<Vec<PjRtBuffer>>> {
        bail!(STUB_MSG)
    }
}

/// A device buffer (stub).
pub struct PjRtBuffer;

impl PjRtBuffer {
    /// Fetch the buffer into a host literal (stub: always fails).
    pub fn to_literal_sync(&self) -> Result<Literal> {
        bail!(STUB_MSG)
    }
}

/// A host-side literal value (stub).
pub struct Literal;

impl Literal {
    /// Read out as a typed vector (stub: always fails).
    pub fn to_vec<T>(&self) -> Result<Vec<T>> {
        bail!(STUB_MSG)
    }

    /// Destructure a tuple literal (stub: always fails).
    pub fn to_tuple(self) -> Result<Vec<Literal>> {
        bail!(STUB_MSG)
    }
}
