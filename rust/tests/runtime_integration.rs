//! Integration tests over a full artifact tree: runtime + engine +
//! strategies. They run against the synthetic reference-backend tree
//! (`ngrammys::testkit`), which has the same layout and manifest schema as
//! the python-built one — so they prove the three layers compose without
//! requiring the `make artifacts` toolchain. With a real tree present
//! (NGRAMMYS_ARTIFACTS + `--features pjrt`) the same tests cover the PJRT
//! path.

use std::sync::Arc;

use ngrammys::bench::BenchCtx;
use ngrammys::config::{EngineConfig, Manifest};
use ngrammys::draft::NgramTables;
use ngrammys::engine::{greedy_config, NoDraft, SpecDecoder};
use ngrammys::kvcache::SharedKvCache;
use ngrammys::scheduler::{make_strategy, StrategyName};
use ngrammys::workload;

fn manifest() -> Manifest {
    ngrammys::testkit::manifest()
}

fn ctx(model: &str) -> BenchCtx {
    BenchCtx::load(manifest(), model).unwrap()
}

#[test]
fn manifest_lists_three_models_and_tasks() {
    let m = manifest();
    for model in ["small", "base", "large"] {
        assert!(m.models.contains_key(model), "missing model {model}");
    }
    for task in workload::TASKS {
        assert!(m.data.contains_key(task), "missing task {task}");
    }
    assert!(m.vocab_size > 256);
}

#[test]
fn prefill_then_greedy_steps_match_repeat_prefill() {
    // decode 8 tokens greedily, then re-prefill with prompt+8 and check the
    // next token matches the 9th greedy step — cache commit correctness.
    let c = ctx("base");
    let prompt = c.tokenizer.encode("def scale(x, y):\n    result");
    let mut dec = SpecDecoder::new(&c.runtime, Box::new(NoDraft), greedy_config(9));
    let r = dec.generate(&prompt).unwrap();
    assert_eq!(r.tokens.len(), 9);

    let mut full = prompt.clone();
    full.extend_from_slice(&r.tokens[..8]);
    let dims = &c.runtime.artifacts().dims;
    let mut cache = SharedKvCache::new(
        dims.n_layers, dims.max_len, dims.n_heads, dims.head_dim);
    let pf = c.runtime.prefill(&full, &mut cache).unwrap();
    assert_eq!(
        pf.next_id, r.tokens[8],
        "incremental KV cache diverged from fresh prefill"
    );
}

#[test]
fn speculative_equals_greedy_for_every_strategy() {
    // THE paper invariant: wrong drafts cost speed, never correctness.
    let c = ctx("base");
    let prompts = [
        "Question: Sam has 40 coins.",
        "def clamp(a, b):",
        "User: What is the capital of",
    ];
    for p in prompts {
        let toks = c.tokenizer.encode(p);
        let mut greedy = SpecDecoder::new(&c.runtime, Box::new(NoDraft), greedy_config(32));
        let want = greedy.generate(&toks).unwrap().tokens;
        for (strat, k, w) in [
            (StrategyName::Mixed, 10, 10),
            (StrategyName::Context, 5, 4),
            (StrategyName::Bigram, 10, 1),
            (StrategyName::Unigram, 5, 1),
            (StrategyName::ExtBigram, 5, 8),
            (StrategyName::Jacobi, 1, 10),
        ] {
            let s = make_strategy(strat, &c.tables, 1);
            let mut dec = SpecDecoder::new(
                &c.runtime,
                s,
                EngineConfig { k, w, q: 1, max_new_tokens: 32 },
            );
            let got = dec.generate(&toks).unwrap();
            assert_eq!(
                got.tokens, want,
                "strategy {strat:?} (k={k}, w={w}) altered the greedy stream for {p:?}"
            );
            assert!(got.calls <= want.len(), "more calls than greedy?!");
        }
    }
}

#[test]
fn mixed_strategy_beats_greedy_on_calls() {
    // in-distribution code prompt: mixed must accept drafts (tok/call > 1.2)
    let c = ctx("base");
    let examples = workload::load_examples(&c.manifest, "code", 4).unwrap();
    let prompts = workload::build_prompts(&c.tokenizer, &examples, 0.4, 96);
    let mut total_tokens = 0usize;
    let mut total_calls = 0usize;
    for p in &prompts {
        let s = make_strategy(StrategyName::Mixed, &c.tables, 1);
        let mut dec = SpecDecoder::new(
            &c.runtime, s, EngineConfig { k: 10, w: 10, q: 1, max_new_tokens: 48 });
        let r = dec.generate(&p.tokens).unwrap();
        total_tokens += r.tokens.len();
        total_calls += r.calls;
    }
    let tpc = total_tokens as f64 / total_calls as f64;
    assert!(tpc > 1.2, "tokens/call {tpc:.2} — speculation is not accepting");
}

#[test]
fn all_three_models_generate() {
    for model in ["small", "base", "large"] {
        let c = ctx(model);
        let toks = c.tokenizer.encode("Question: Tom has 5 apples.");
        let s = make_strategy(StrategyName::Mixed, &c.tables, 1);
        let mut dec = SpecDecoder::new(
            &c.runtime, s, EngineConfig { k: 5, w: 4, q: 1, max_new_tokens: 16 });
        let r = dec.generate(&toks).unwrap();
        assert_eq!(r.tokens.len(), 16, "model {model}");
        assert!(r.tokens.iter().all(|&t| (t as usize) < c.manifest.vocab_size));
    }
}

#[test]
fn long_generation_respects_cache_capacity() {
    // push generation until the cache nearly fills; must not error and the
    // engine must shrink w near the end rather than overflow.
    let c = ctx("small");
    let toks = c.tokenizer.encode("User: Tell me about ancient rivers.");
    let s = make_strategy(StrategyName::Mixed, &c.tables, 1);
    let max_len = c.runtime.artifacts().dims.max_len;
    let budget = max_len - toks.len() - 16;
    let mut dec = SpecDecoder::new(
        &c.runtime, s, EngineConfig { k: 10, w: 10, q: 1, max_new_tokens: budget });
    let r = dec.generate(&toks).unwrap();
    assert!(r.tokens.len() as f64 >= budget as f64 * 0.9,
            "generated {} of {budget}", r.tokens.len());
}

#[test]
fn runtime_rejects_overlong_prompt_and_bad_shapes() {
    let c = ctx("small");
    let dims = c.runtime.artifacts().dims.clone();
    let long = vec![1u32; 300]; // > largest prefill bucket (256)
    let mut cache = SharedKvCache::new(
        dims.n_layers, dims.max_len, dims.n_heads, dims.head_dim);
    assert!(c.runtime.prefill(&long, &mut cache).is_err());
    assert!(c.runtime.prefill(&[], &mut cache).is_err());
    // no (3, 3) artifact shape exists
    assert!(c.runtime.spec_step(3, 3, &vec![0; 12], &cache).is_err());
    // token count mismatch
    assert!(c.runtime.spec_step(5, 4, &vec![0; 7], &cache).is_err());
}

#[test]
fn tables_load_and_are_well_formed() {
    let m = manifest();
    for model in ["small", "base", "large"] {
        let art = m.model(model).unwrap();
        let t = NgramTables::load(art).unwrap();
        let v = art.dims.vocab_size as u32;
        assert_eq!(t.bigram.rows as u32, v);
        for r in 0..t.bigram.rows {
            for c2 in 0..t.bigram.cols {
                assert!(t.bigram.at(r, c2) < v, "bigram[{r}][{c2}] out of vocab");
            }
        }
        assert!(t.unigram.cols >= 32);
        let _ = Arc::new(t);
    }
}

#[test]
fn step_trace_ctx_len_is_captured_at_call_time() {
    // regression: ctx_len must be the cache length the verifier attended
    // over (BEFORE the step's commit), i.e. the first call sees exactly
    // the prompt length and each later call sees the previous ctx_len
    // plus the tokens the previous call committed (accepted + 1).
    let c = ctx("small");
    let prompt = c.tokenizer.encode("Question: Mia has 4 coins. Mia buys 3 more.");
    let s = make_strategy(StrategyName::Mixed, &c.tables, 1);
    let mut dec = SpecDecoder::new(
        &c.runtime, s, EngineConfig { k: 5, w: 4, q: 1, max_new_tokens: 24 });
    dec.collect_traces = true;
    let r = dec.generate(&prompt).unwrap();
    assert!(!r.traces.is_empty());
    assert_eq!(
        r.traces[0].ctx_len,
        prompt.len(),
        "first verification call must see exactly the prefilled prompt"
    );
    let mut expect = prompt.len();
    for t in &r.traces {
        assert_eq!(t.ctx_len, expect, "ctx_len mislabeled mid-stream");
        expect += t.accepted + 1; // the call committed accepted + bonus
    }
}

#[test]
fn best_fitting_shape_prefers_exact_then_shrinks() {
    let c = ctx("base");
    assert_eq!(c.runtime.best_fitting_shape(10, 10, 512), Some((10, 10)));
    assert_eq!(c.runtime.best_fitting_shape(1, 0, 512), Some((1, 0)));
    // little cache room left: w must shrink below requested
    let s = c.runtime.best_fitting_shape(10, 10, 4).unwrap();
    assert!(s.1 + 1 <= 4);
    // nothing fits in zero room
    assert_eq!(c.runtime.best_fitting_shape(10, 10, 0), None);
}
