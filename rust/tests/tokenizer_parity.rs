//! Tokenizer parity through the shared artifact tree: (1) rust round-trips
//! the corpora losslessly, (2) rust encodings match the fixture encodings
//! captured at artifact-build time (python's `compile.fixtures` for a real
//! tree; the testkit's trained BPE for the synthetic one — either way the
//! merge machinery is exercised against a frozen reference).

use ngrammys::config::Manifest;
use ngrammys::tokenizer::BpeTokenizer;
use ngrammys::util::json::Json;

fn load() -> (Manifest, BpeTokenizer) {
    let m = ngrammys::testkit::manifest();
    let t = BpeTokenizer::load(&m.tokenizer_path).unwrap();
    (m, t)
}

#[test]
fn roundtrips_all_corpora_losslessly() {
    let (m, tok) = load();
    for (task, (train, eval)) in &m.data {
        for path in [train, eval] {
            let text = std::fs::read_to_string(path).unwrap();
            let ids = tok.encode(&text);
            assert_eq!(tok.decode(&ids), text, "task {task} path {path:?}");
            assert!(
                ids.iter().all(|&i| (i as usize) < tok.vocab_size),
                "out-of-vocab id in {task}"
            );
            // BPE must actually compress the corpus it was trained on
            assert!(
                ids.len() * 2 < text.len(),
                "poor compression on {task}: {} ids for {} bytes",
                ids.len(),
                text.len()
            );
        }
    }
}

#[test]
fn matches_python_fixture_encodings() {
    let (m, tok) = load();
    let path = m.root.join("tokenizer_fixtures.json");
    let text = std::fs::read_to_string(&path)
        .expect("tokenizer_fixtures.json missing — run `make artifacts`");
    let j = Json::parse(&text).unwrap();
    let cases = j.req("cases").unwrap().as_arr().unwrap();
    assert!(cases.len() >= 8, "too few fixture cases");
    for case in cases {
        let s = case.req("text").unwrap().as_str().unwrap();
        let want: Vec<u32> = case
            .req("ids")
            .unwrap()
            .as_arr()
            .unwrap()
            .iter()
            .map(|v| v.as_usize().unwrap() as u32)
            .collect();
        assert_eq!(tok.encode(s), want, "python/rust disagree on {s:?}");
    }
}
