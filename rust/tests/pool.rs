//! Engine-pool invariants: multi-engine scale-out (spawn AND retire, mid
//! traffic), depth-aware routing, and the per-class packed-depth split
//! must never change a single output byte — every stream stays exactly
//! the per-sequence greedy continuation of its prompt — while mixed
//! greedy + speculative traffic keeps its speculative tokens/call
//! instead of collapsing to depth 0.

use std::sync::atomic::Ordering;

use ngrammys::bench::BenchCtx;
use ngrammys::config::{EngineConfig, ServeConfig};
use ngrammys::engine::{
    batched::generate_all, greedy_config, BatchedEngine, NoDraft, SpecDecoder,
};
use ngrammys::scheduler::{
    make_strategy, DepthClass, EngineScaleConfig, GenRequest, Scheduler, StrategyName,
};

fn ctx(model: &str) -> BenchCtx {
    BenchCtx::load(ngrammys::testkit::manifest(), model).unwrap()
}

fn greedy_stream(c: &BenchCtx, prompt: &[u32], max_new: usize) -> Vec<u32> {
    let mut dec = SpecDecoder::new(&c.runtime, Box::new(NoDraft), greedy_config(max_new));
    dec.generate(prompt).unwrap().tokens
}

const TEXTS: [&str; 8] = [
    "Question: Tom has 4 apples. Tom buys 2 more.",
    "def scale(x, y):\n    result",
    "User: What is the capital of France?",
    "Answer: Mia has 5 coins.",
    "def blend(value, count):",
    "User: Tell me about ancient rivers.",
    "Question: Sam has 7 cards.",
    "Assistant: That is a good question.",
];

/// Mixed-traffic request: every third request is greedy (w = 0).
fn req(c: &BenchCtx, text: &str, i: usize, max_new: usize) -> GenRequest {
    let greedy = i % 3 == 2;
    GenRequest {
        prompt: c.tokenizer.encode(text),
        engine: EngineConfig {
            k: if greedy { 1 } else { 10 },
            w: if greedy { 0 } else { 10 },
            q: 1,
            max_new_tokens: max_new,
        },
        strategy: if greedy { StrategyName::None } else { StrategyName::Mixed },
    }
}

/// The full pool scheduler (two-level autoscaling + depth-aware routing,
/// `elastic: true` default) returns byte-identical streams to
/// per-sequence greedy decoding at engine caps 1/2/4, across TWO bursts
/// with an idle gap between them — the trajectory that exercises engine
/// spawn (burst pressure), idle retire (the gap) and respawn (second
/// burst). The per-engine gauges must be populated afterwards.
#[test]
fn pool_is_lossless_across_engine_caps_and_spawn_retire() {
    let c = ctx("small");
    let max_new = 12;
    let want: Vec<Vec<u32>> = TEXTS
        .iter()
        .enumerate()
        .map(|(i, t)| {
            let r = req(&c, t, i, max_new);
            greedy_stream(&c, &r.prompt, max_new)
        })
        .collect();

    for cap in [1usize, 2, 4] {
        let cfg = ServeConfig {
            addr: "127.0.0.1:0".into(),
            queue_cap: 64,
            batch: 3, // per-engine lane cap: one burst overflows one engine
            engines: cap,
            // fast two-level scaling so the short test really spawns and
            // retires engines (up after 1 pressure tick, down after 2)
            engine_scale: EngineScaleConfig {
                min_engines: 1,
                max_engines: cap,
                up_after_steps: 1,
                down_after_steps: 2,
            },
            ..ServeConfig::default()
        };
        assert!(cfg.elastic, "elastic must be the batched-mode default");
        let sched = Scheduler::start(&ngrammys::testkit::manifest(), "small", &cfg).unwrap();

        for wave in 0..2 {
            // submit the whole burst at once: the queue backs up behind
            // one engine's lanes and the pool must scale out (cap > 1)
            let rxs: Vec<_> = TEXTS
                .iter()
                .enumerate()
                .map(|(i, t)| sched.submit(req(&c, t, i, max_new)).unwrap())
                .collect();
            for (i, rx) in rxs.into_iter().enumerate() {
                let got = rx.recv().unwrap().unwrap();
                assert_eq!(
                    got.tokens, want[i],
                    "cap {cap} wave {wave} prompt {i}: stream diverged in the pool"
                );
            }
            // idle gap: the dispatcher parks, retiring surplus engines
            // down to min_engines before it blocks — the second wave then
            // respawns them
            std::thread::sleep(std::time::Duration::from_millis(20));
        }

        let engines = sched.metrics.engines.load(Ordering::Relaxed);
        assert!(
            engines >= 1 && engines as usize <= cap,
            "cap {cap}: engines gauge {engines} outside [1, {cap}]"
        );
        assert!(sched.metrics.lanes.load(Ordering::Relaxed) >= 1, "lanes gauge never set");
        assert!(
            sched.metrics.derived_budget.load(Ordering::Relaxed) >= 1,
            "derived budget gauge never set"
        );
        let rendered = sched.metrics.render();
        assert!(rendered.contains("ngrammys_engines "));
        assert!(rendered.contains("ngrammys_engines_target "));
        assert!(rendered.contains("ngrammys_routing_fallbacks "));
        assert!(
            rendered.contains("ngrammys_engine_lanes{engine=\""),
            "per-engine gauge families missing:\n{rendered}"
        );
        sched.shutdown();
    }
}

/// REGRESSION PIN (mixed traffic): a w = 0 admission used to drag every
/// co-resident sequence's packed depth to the global minimum 0, so
/// speculative tokens/call collapsed to ~1. With the per-class depth
/// split, speculative sequences keep their depth (and their exact output
/// bytes), and the step's packed calls show BOTH a w = 0 group and a
/// w > 0 group while the classes coexist.
#[test]
fn greedy_admission_does_not_collapse_speculative_depth() {
    let c = ctx("small");
    let max_new = 20;
    let spec_cfg = EngineConfig { k: 10, w: 10, q: 1, max_new_tokens: max_new };
    let spec_prompts: Vec<Vec<u32>> =
        TEXTS[..4].iter().map(|t| c.tokenizer.encode(t)).collect();
    let greedy_prompts: Vec<Vec<u32>> =
        TEXTS[4..6].iter().map(|t| c.tokenizer.encode(t)).collect();

    // baseline: speculative population only
    let mut base_eng = BatchedEngine::new(&c.runtime, 4);
    base_eng.collect_traces = true;
    let base_reqs = spec_prompts
        .iter()
        .map(|p| {
            (
                p.clone(),
                make_strategy(StrategyName::Mixed, &c.tables, 1),
                spec_cfg.clone(),
            )
        })
        .collect();
    let base = generate_all(&mut base_eng, base_reqs).unwrap();
    let base_tpc: f64 = base.iter().map(|r| r.tokens_per_call()).sum::<f64>() / base.len() as f64;

    // mixed: same speculative population + co-resident greedy requests
    let mut eng = BatchedEngine::new(&c.runtime, 6);
    eng.collect_traces = true;
    let mut reqs: Vec<(Vec<u32>, Box<dyn ngrammys::draft::DraftStrategy>, EngineConfig)> =
        Vec::new();
    for p in &spec_prompts {
        reqs.push((p.clone(), make_strategy(StrategyName::Mixed, &c.tables, 1), spec_cfg.clone()));
    }
    for p in &greedy_prompts {
        reqs.push((p.clone(), Box::new(NoDraft), greedy_config(max_new)));
    }
    let mixed = generate_all(&mut eng, reqs).unwrap();

    // byte-identity: speculative streams are EXACTLY the baseline's (and
    // the greedy streams are the per-sequence greedy continuations)
    for (i, r) in mixed[..4].iter().enumerate() {
        assert_eq!(r.tokens, base[i].tokens, "spec stream {i} changed when greedy joined");
    }
    for (i, r) in mixed[4..].iter().enumerate() {
        assert_eq!(
            r.tokens,
            greedy_stream(&c, &greedy_prompts[i], max_new),
            "greedy stream {i} diverged"
        );
    }

    // the acceptance bar: speculative tokens/call with co-resident
    // greedy traffic within 10% of the greedy-free baseline (the old
    // global-minimum depth collapsed it to ~1.0)
    let mixed_tpc: f64 =
        mixed[..4].iter().map(|r| r.tokens_per_call()).sum::<f64>() / 4.0;
    assert!(
        mixed_tpc >= base_tpc * 0.9,
        "speculative tokens/call degraded: mixed {mixed_tpc:.2} vs baseline {base_tpc:.2}"
    );

    // the packed calls themselves: while both classes are resident, a
    // step issues a w = 0 group AND a w > 0 group — no global minimum
    let mut saw_split_step = false;
    for t in &eng.packed_traces {
        if t.w > 0
            && eng
                .packed_traces
                .iter()
                .any(|u| u.step == t.step && u.w == 0)
        {
            saw_split_step = true;
            break;
        }
    }
    assert!(
        saw_split_step,
        "no step packed both a w=0 and a w>0 call; traces: {:?}",
        eng.packed_traces
            .iter()
            .map(|t| (t.step, t.w, t.rows))
            .collect::<Vec<_>>()
    );
    assert!(
        eng.packed_traces.iter().any(|t| t.w > 1),
        "speculative group never ran deeper than w=1 with greedy co-resident"
    );
}

/// Depth classes derive from strategy + shape exactly like the admission
/// scorer prices them.
#[test]
fn depth_class_of_request() {
    let spec = EngineConfig { k: 10, w: 10, q: 1, max_new_tokens: 8 };
    let flat = EngineConfig { k: 10, w: 0, q: 1, max_new_tokens: 8 };
    assert_eq!(DepthClass::of(StrategyName::Mixed, &spec), DepthClass::Speculative);
    assert_eq!(DepthClass::of(StrategyName::None, &spec), DepthClass::Greedy);
    assert_eq!(DepthClass::of(StrategyName::Mixed, &flat), DepthClass::Greedy);
    assert_eq!(DepthClass::of(StrategyName::Adaptive, &spec), DepthClass::Speculative);
}
