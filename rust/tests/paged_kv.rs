//! Paged-KV integration: the paged pool with refcounted copy-on-write
//! prefix sharing must be byte-identical to the contiguous lane pool on
//! every stream (the lane pool is the differential oracle), its refcount
//! and budget accounting must balance under arbitrary trajectories, and
//! shared pages must never let one sequence's writes leak into another.

use ngrammys::bench::BenchCtx;
use ngrammys::config::EngineConfig;
use ngrammys::engine::{generate_all, BatchedEngine};
use ngrammys::kvcache::paged::PagedKvPool;
use ngrammys::kvcache::{KvRead, KvWrite};
use ngrammys::scheduler::{make_strategy, StrategyName};
use ngrammys::tokenizer::TokenId;
use ngrammys::util::prop;
use ngrammys::util::rng::Rng;
use ngrammys::workload::shared_prefix_prompts;

fn ctx(model: &str) -> BenchCtx {
    BenchCtx::load(ngrammys::testkit::manifest(), model).unwrap()
}

fn prompts(c: &BenchCtx) -> Vec<Vec<u32>> {
    [
        "Question: Tom has 4 apples. Tom buys 2 more.",
        "def scale(x, y):\n    result",
        "User: What is the capital of France?",
        "Answer: Mia has 5 coins.",
        "def blend(value, count):",
        "User: Tell me about ancient rivers.",
        "Question: Sam has 7 cards.",
        "Assistant: That is a good question.",
    ]
    .iter()
    .map(|p| c.tokenizer.encode(p))
    .collect()
}

/// THE tentpole acceptance test: at concurrency 1, 4 and 8, the engine
/// on the paged pool produces byte-identical token streams to the engine
/// on the lane pool, for mixed/context/greedy strategies.
#[test]
fn paged_streams_match_lane_pool_oracle_at_conc_1_4_8() {
    let c = ctx("small");
    let prompts = prompts(&c);
    for (strat, k, w) in [
        (StrategyName::Mixed, 10, 10),
        (StrategyName::Context, 5, 4),
        (StrategyName::None, 1, 0),
    ] {
        let cfg = EngineConfig { k, w, q: 1, max_new_tokens: 20 };
        for conc in [1usize, 4, 8] {
            let reqs = |c: &BenchCtx| -> Vec<_> {
                prompts
                    .iter()
                    .map(|p| (p.clone(), make_strategy(strat, &c.tables, 1), cfg.clone()))
                    .collect()
            };
            let mut lane = BatchedEngine::new(&c.runtime, conc);
            let want = generate_all(&mut lane, reqs(&c)).unwrap();
            let mut paged = BatchedEngine::new_paged(&c.runtime, conc, 16, 0);
            let got = generate_all(&mut paged, reqs(&c)).unwrap();
            for (i, (g, w_)) in got.iter().zip(&want).enumerate() {
                assert_eq!(
                    g.tokens, w_.tokens,
                    "strategy {strat:?} conc {conc} prompt {i}: paged stream diverged \
                     from the lane-pool oracle"
                );
            }
        }
    }
}

/// The capacity claim, pinned as a test: with the SAME byte budget the
/// lane pool would spend on 2 lanes, the paged pool admits strictly more
/// shared-system-prompt sequences.
#[test]
fn paged_pool_admits_more_shared_prompt_lanes() {
    let c = ctx("small");
    let d = &c.runtime.artifacts().dims;
    let page_size = 16usize;
    let lanes = 2usize;
    let n_pages = lanes * d.max_len.div_ceil(page_size);
    let prefix_len = (d.max_len / 2 / page_size) * page_size;
    let prompts = shared_prefix_prompts(11, 16, prefix_len, 6, c.manifest.vocab_size);
    let cfg = EngineConfig { k: 5, w: 4, q: 1, max_new_tokens: 12 };

    let mut lane_eng = BatchedEngine::new(&c.runtime, lanes);
    let mut paged_eng = BatchedEngine::new_paged(&c.runtime, prompts.len(), page_size, n_pages);
    let admit_all = |eng: &mut BatchedEngine| {
        let mut n = 0usize;
        for p in &prompts {
            if !eng.can_admit_prompt(p, &cfg) {
                break;
            }
            eng.admit(p, make_strategy(StrategyName::Mixed, &c.tables, 1), cfg.clone())
                .unwrap();
            n += 1;
        }
        n
    };
    let lane_n = admit_all(&mut lane_eng);
    let paged_n = admit_all(&mut paged_eng);
    assert_eq!(lane_n, lanes, "lane pool admits exactly its lane count");
    assert!(
        paged_n > lane_n,
        "paged pool admitted {paged_n} <= lane pool {lane_n} from the same bytes"
    );
    let stats = paged_eng.page_stats();
    assert_eq!(
        stats.prefix_hits,
        (paged_n - 1) as u64,
        "every admission after the first should attach shared prefix pages"
    );
    assert!(stats.shared > 0, "shared-page gauge should be live");
}

/// Value encoding for the property trajectories: a pure function of
/// (layer, token, elem) — position-independent, so two sequences with the
/// same token at the same position legitimately share bytes, and any
/// cross-sequence leak shows up as a token mismatch on read-back.
fn enc(l: usize, t: TokenId, e: usize) -> f32 {
    (l * 100_000) as f32 + (t * 10) as f32 + e as f32
}

/// Dense (layers, max_len, heads*head_dim) install buffers encoding
/// `tokens`, mirroring how the reference backend fills a prefill.
fn dense(tokens: &[TokenId], layers: usize, max_len: usize, ps: usize) -> (Vec<f32>, Vec<f32>) {
    let mut k = vec![0.0f32; layers * max_len * ps];
    let mut v = vec![0.0f32; layers * max_len * ps];
    for l in 0..layers {
        for (pos, &t) in tokens.iter().enumerate() {
            for e in 0..ps {
                k[(l * max_len + pos) * ps + e] = enc(l, t, e);
                v[(l * max_len + pos) * ps + e] = -enc(l, t, e) - 1.0;
            }
        }
    }
    (k, v)
}

/// A (layers, k_rows, w1, heads*head_dim) commit tail carrying `toks` on
/// `row`; every other row is poison, so a commit that reads the wrong
/// row contaminates visibly.
fn tail(
    toks: &[TokenId],
    layers: usize,
    k_rows: usize,
    w1: usize,
    row: usize,
    ps: usize,
) -> (Vec<f32>, Vec<f32>) {
    let n = layers * k_rows * w1 * ps;
    let mut k = vec![9e6f32; n];
    let mut v = vec![-9e6f32; n];
    for l in 0..layers {
        for (d, &t) in toks.iter().enumerate() {
            for e in 0..ps {
                let idx = ((l * k_rows + row) * w1 + d) * ps + e;
                k[idx] = enc(l, t, e);
                v[idx] = -enc(l, t, e) - 1.0;
            }
        }
    }
    (k, v)
}

/// One sequence the trajectory tracks: its pool id, the tokens it has
/// committed (the read-back expectation), and its admission bounds.
struct Live {
    sid: usize,
    toks: Vec<TokenId>,
    max_pos: usize,
    prompt_len: usize,
}

/// Drive a random admit/install/commit/truncate/release trajectory.
/// After EVERY operation the pool must pass its internal audit (refcount
/// balance, reserve accounting, budget invariant) and, when
/// `check_bytes`, every live sequence must read back exactly its own
/// tokens through the page indirection. Truncation never rewinds below
/// the prompt — the engine's rollback floor — and a commit is allowed to
/// fail ONLY with reservation exhaustion (copy-on-write backpressure),
/// which is a clean error, never corruption.
fn trajectory(rng: &mut Rng, check_bytes: bool) -> bool {
    let layers = rng.range(1, 2);
    let (heads, hd) = (1usize, 2usize);
    let ps = heads * hd;
    let psz = rng.range(2, 4);
    let max_len = psz * rng.range(3, 6);
    let budget = rng.range(6, 14);
    let mut pool = PagedKvPool::new(layers, max_len, heads, hd, psz, budget, 4);
    let system: Vec<TokenId> = (0..max_len).map(|_| rng.below(30) as TokenId).collect();
    let mut live: Vec<Live> = Vec::new();

    for _ in 0..24 {
        let op = rng.below(4);
        if op == 0 {
            // admit + install (half the admissions share the system prompt
            // prefix, so refcounted pages really appear)
            let plen = rng.range(1, max_len - 2);
            let mut prompt: Vec<TokenId> = if rng.below(2) == 0 {
                system[..plen].to_vec()
            } else {
                (0..plen).map(|_| rng.below(30) as TokenId).collect()
            };
            prompt.truncate(plen);
            let max_pos = rng.range(plen, max_len);
            if pool.can_admit(&prompt, max_pos) {
                let sid = pool.acquire(&prompt, max_pos).unwrap();
                let (k, v) = dense(&prompt, layers, max_len, ps);
                pool.writer(sid).install(k, v, plen).unwrap();
                pool.sync_tokens(sid, &prompt);
                live.push(Live { sid, toks: prompt, max_pos, prompt_len: plen });
            }
        } else if op == 1 && !live.is_empty() {
            // commit 1-2 tokens within the admission reservation
            let i = rng.below(live.len());
            let room = live[i].max_pos - live[i].toks.len();
            if room > 0 {
                let count = rng.range(1, room.min(2));
                let toks: Vec<TokenId> = (0..count).map(|_| rng.below(30) as TokenId).collect();
                let k_rows = rng.range(1, 2);
                let w1 = count + rng.below(2);
                let row = rng.below(k_rows);
                let (kt, vt) = tail(&toks, layers, k_rows, w1, row, ps);
                let s = &mut live[i];
                match pool.writer(s.sid).commit_tail(&kt, &vt, k_rows, w1, row, count) {
                    Ok(()) => {
                        s.toks.extend(toks);
                        let mirror = s.toks.clone();
                        pool.sync_tokens(s.sid, &mirror);
                    }
                    Err(e) => {
                        if !e.to_string().contains("reservation exhausted") {
                            return false; // only COW backpressure may fail
                        }
                    }
                }
            }
        } else if op == 2 && !live.is_empty() {
            // rollback: truncate somewhere between prompt and current len
            let i = rng.below(live.len());
            let s = &mut live[i];
            let new_len = rng.range(s.prompt_len, s.toks.len());
            pool.writer(s.sid).truncate(new_len).unwrap();
            s.toks.truncate(new_len);
            let mirror = s.toks.clone();
            pool.sync_tokens(s.sid, &mirror);
        } else if op == 3 && !live.is_empty() {
            let s = live.swap_remove(rng.below(live.len()));
            pool.release(s.sid);
        }

        if pool.audit().is_err() {
            return false;
        }
        if check_bytes {
            for s in &live {
                let view = pool.view(s.sid);
                if view.ctx_len() != s.toks.len() {
                    return false;
                }
                for l in 0..layers {
                    for (pos, &t) in s.toks.iter().enumerate() {
                        let (kk, vv) = (view.k_at(l, pos), view.v_at(l, pos));
                        for e in 0..ps {
                            if kk[e] != enc(l, t, e) || vv[e] != -enc(l, t, e) - 1.0 {
                                return false; // cross-sequence contamination
                            }
                        }
                    }
                }
            }
        }
    }
    for s in live {
        pool.release(s.sid);
    }
    // fully drained: refcounts balanced back to zero live pages and the
    // whole budget reclaimable
    pool.audit().is_ok() && pool.in_use() == 0 && pool.page_stats().live == 0
}

/// Property: refcount/reserve/budget accounting balances after every
/// operation of arbitrary trajectories, and drains back to zero.
#[test]
fn prop_paged_refcounts_balance_over_random_trajectories() {
    prop::check(80, |rng: &mut Rng| trajectory(rng, false));
}

/// Property: through arbitrary interleavings of shared-prefix admissions,
/// commits, rollbacks and releases, every sequence reads back exactly its
/// own tokens — shared pages never leak one sequence's writes to another.
#[test]
fn prop_shared_pages_never_cross_contaminate() {
    prop::check(80, |rng: &mut Rng| trajectory(rng, true));
}
