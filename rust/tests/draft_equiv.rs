//! Byte-identity of the rewritten draft layer against the SEED
//! implementations.
//!
//! The PR that introduced the incremental suffix index and the
//! arena-backed `DraftBatch` claims zero behavioral change: every
//! strategy must propose exactly the rows (tokens, kind, rank,
//! confidence) the seed code proposed, across arbitrary sequences AND
//! across append/rollback trajectories of one persistent instance. The
//! oracles here are the seed algorithms themselves: the library keeps
//! the seed context rescan as `reference_candidates`, and this file
//! carries verbatim ports of the seed session-cache and mixed-policy
//! code.

use std::collections::HashMap;
use std::sync::Arc;

use ngrammys::draft::context_ngram::reference_candidates;
use ngrammys::draft::tables::Table;
use ngrammys::draft::{
    count_share, ContextNgram, DraftBatch, DraftStrategy, MixedStrategy, NgramTables,
    SessionNgramCache, StrategyKind,
};
use ngrammys::util::prop;
use ngrammys::util::rng::Rng;

/// Flatten a batch into comparable row records.
fn rows_of(b: &DraftBatch) -> Vec<(Vec<u32>, StrategyKind, usize, f64)> {
    (0..b.k())
        .map(|r| {
            let d = &b.rows()[r];
            (b.row_tokens(r).to_vec(), d.kind, d.rank, d.confidence)
        })
        .collect()
}

fn random_tables(rng: &mut Rng, vocab: usize, topk: usize, depth: usize) -> Arc<NgramTables> {
    let mut mk = |n: usize| -> Vec<u32> { (0..n).map(|_| rng.below(vocab) as u32).collect() };
    let bigram = mk(vocab * topk);
    let unigram = mk(topk);
    let ext = mk(vocab * topk * depth);
    Arc::new(NgramTables {
        bigram: Table::from_data(vocab, topk, 1, bigram),
        unigram: Table::from_data(1, topk, 1, unigram),
        ext_bigram: Table::from_data(vocab, topk, depth, ext),
    })
}

/// What the seed ContextNgram::propose pushed, built from the seed rescan.
fn seed_context_rows(
    q: usize,
    seq: &[u32],
    k: usize,
    w: usize,
) -> Vec<(Vec<u32>, StrategyKind, usize, f64)> {
    let cands = reference_candidates(q, seq, w);
    let total: u32 = cands.iter().map(|(_, c)| *c).sum();
    cands
        .into_iter()
        .enumerate()
        .take(k)
        .map(|(rank, (tokens, count))| {
            let conf = count_share(count, total).clamp(f64::MIN_POSITIVE, 1.0);
            (tokens, StrategyKind::ContextNgram, rank, conf)
        })
        .collect()
}

#[test]
fn context_ngram_matches_seed_on_random_sequences() {
    prop::check(400, |rng| {
        let vocab = rng.range(2, 10) as u32; // small vocab -> many matches
        let len = rng.range(0, 200);
        let q = rng.range(1, 3);
        let w = rng.range(1, 8);
        let k = rng.range(1, 12);
        let seq = prop::vec_u32(rng, len, 0..vocab);
        let mut ctx = ContextNgram::new(q);
        let mut b = DraftBatch::new(w);
        ctx.propose(&seq, k, &mut b);
        rows_of(&b) == seed_context_rows(q, &seq, k, w)
    });
}

#[test]
fn context_ngram_matches_seed_across_rollback_trajectories() {
    // ONE persistent instance whose sequence grows and rolls back, as
    // under rejected speculation — every proposal must still equal a
    // from-scratch seed rescan of the current sequence
    prop::check(150, |rng| {
        let vocab = rng.range(2, 8) as u32;
        let q = rng.range(1, 3);
        let mut ctx = ContextNgram::new(q);
        let mut seq: Vec<u32> = Vec::new();
        for _ in 0..rng.range(4, 25) {
            match rng.below(4) {
                // accepted tokens appended (the decode common case)
                0 | 1 => {
                    for _ in 0..rng.range(1, 8) {
                        seq.push(rng.below(vocab as usize) as u32);
                    }
                }
                // rollback (rejected speculation / divergent caller)
                2 => {
                    let keep = if seq.is_empty() { 0 } else { rng.below(seq.len() + 1) };
                    seq.truncate(keep);
                }
                // divergence: rollback then different tokens
                _ => {
                    let keep = if seq.is_empty() { 0 } else { rng.below(seq.len() + 1) };
                    seq.truncate(keep);
                    for _ in 0..rng.range(1, 5) {
                        seq.push(rng.below(vocab as usize) as u32);
                    }
                }
            }
            let w = rng.range(1, 6);
            let k = rng.range(1, 8);
            let mut b = DraftBatch::new(w);
            ctx.propose(&seq, k, &mut b);
            if rows_of(&b) != seed_context_rows(q, &seq, k, w) {
                return false;
            }
        }
        true
    });
}

// ---------------------------------------------------------------------------
// Seed SessionNgramCache, ported verbatim (per-position chain clone, full
// re-sort per ingested position, tail clone per observe).

struct SeedSessionCache {
    table: HashMap<u32, Vec<(Vec<u32>, u32)>>,
    per_query: usize,
    max_chain: usize,
    stored: usize,
    cap: usize,
    tail: Vec<u32>,
}

impl SeedSessionCache {
    fn new(per_query: usize, max_chain: usize, cap: usize) -> Self {
        SeedSessionCache {
            table: HashMap::new(),
            per_query,
            max_chain,
            stored: 0,
            cap,
            tail: Vec::new(),
        }
    }

    fn ingest(&mut self, span: &[u32]) {
        for i in 0..span.len().saturating_sub(1) {
            let q = span[i];
            let chain: Vec<u32> = span[i + 1..].iter().copied().take(self.max_chain).collect();
            if chain.is_empty() {
                continue;
            }
            let entry = self.table.entry(q).or_default();
            if let Some(e) = entry
                .iter_mut()
                .find(|(c, _)| c.starts_with(&chain) || chain.starts_with(c))
            {
                if chain.len() > e.0.len() {
                    e.0 = chain;
                }
                e.1 += 1;
            } else if entry.len() < self.per_query && self.stored < self.cap {
                entry.push((chain, 1));
                self.stored += 1;
            }
            entry.sort_by(|a, b| b.1.cmp(&a.1));
        }
    }

    fn propose(&self, seq: &[u32], k: usize, w: usize) -> Vec<(Vec<u32>, StrategyKind, usize, f64)> {
        let mut rows = Vec::new();
        let Some(&cur) = seq.last() else { return rows };
        if let Some(conts) = self.table.get(&cur) {
            let total: u32 = conts.iter().map(|(_, c)| *c).sum();
            for (rank, (chain, count)) in conts.iter().enumerate() {
                if rows.len() >= k {
                    break;
                }
                let toks: Vec<u32> = chain.iter().copied().take(w).collect();
                let conf = count_share(*count, total).clamp(f64::MIN_POSITIVE, 1.0);
                rows.push((toks, StrategyKind::SessionCache, rank, conf));
            }
        }
        rows
    }

    fn observe(&mut self, accepted: &[u32]) {
        self.tail.extend_from_slice(accepted);
        if self.tail.len() > self.max_chain + 1 {
            let span: Vec<u32> = self.tail.clone();
            self.ingest(&span);
            let keep = self.max_chain.min(self.tail.len());
            self.tail.drain(..self.tail.len() - keep);
        }
    }
}

#[test]
fn session_cache_matches_seed_across_observe_streams() {
    prop::check(200, |rng| {
        let vocab = rng.range(2, 12) as u32;
        let per_query = rng.range(1, 6);
        let max_chain = rng.range(1, 6);
        let cap = rng.range(1, 40);
        let mut new = SessionNgramCache::new(per_query, max_chain, cap);
        let mut seed = SeedSessionCache::new(per_query, max_chain, cap);
        for _ in 0..rng.range(2, 20) {
            if rng.f64() < 0.7 {
                let span = prop::vec_u32(rng, rng.range(0, 10), 0..vocab);
                new.observe(&span, &[]);
                seed.observe(&span);
            } else {
                new.reset();
                seed.tail.clear();
            }
            // propose after every mutation and compare
            let probe = prop::vec_u32(rng, rng.range(1, 4), 0..vocab);
            let k = rng.range(1, 8);
            let w = rng.range(1, 6);
            let mut b = DraftBatch::new(w);
            new.propose(&probe, k, &mut b);
            if rows_of(&b) != seed.propose(&probe, k, w) {
                return false;
            }
            if new.len() != seed.stored {
                return false;
            }
        }
        true
    });
}

#[test]
fn session_cache_direct_ingest_matches_seed() {
    prop::check(200, |rng| {
        let vocab = rng.range(2, 8) as u32;
        let per_query = rng.range(1, 5);
        let max_chain = rng.range(1, 5);
        let cap = rng.range(1, 30);
        let mut new = SessionNgramCache::new(per_query, max_chain, cap);
        let mut seed = SeedSessionCache::new(per_query, max_chain, cap);
        for _ in 0..rng.range(1, 8) {
            let span = prop::vec_u32(rng, rng.range(0, 14), 0..vocab);
            new.ingest(&span);
            seed.ingest(&span);
        }
        let probe = prop::vec_u32(rng, 1, 0..vocab);
        let mut b = DraftBatch::new(4);
        new.propose(&probe, 16, &mut b);
        rows_of(&b) == seed.propose(&probe, 16, 4) && new.len() == seed.stored
    });
}

// ---------------------------------------------------------------------------
// Seed MixedStrategy::propose (ContextFirst), ported verbatim: gather both
// sources into ranked row lists, then push DISTINCT rows in policy order.

fn seed_mixed_rows(
    tables: &NgramTables,
    q: usize,
    seq: &[u32],
    k: usize,
    w: usize,
) -> Vec<(Vec<u32>, StrategyKind, usize, f64)> {
    let ctx_cands = reference_candidates(q, seq, w);
    let ctx_total: u32 = ctx_cands.iter().map(|(_, c)| *c).sum();
    let ctx_rows: Vec<(Vec<u32>, f64)> = ctx_cands
        .into_iter()
        .map(|(g, c)| (g, count_share(c, ctx_total)))
        .collect();
    let mut big_rows: Vec<(Vec<u32>, f64)> = Vec::new();
    if let Some(&cur) = seq.last() {
        let mut chain = Vec::new();
        for j in 0..tables.ext_bigram.cols {
            tables.ext_chain(cur, j, w, &mut chain);
            big_rows.push((chain.clone(), 1.0 / (1.0 + j as f64)));
        }
    }
    let mut out: Vec<(Vec<u32>, StrategyKind, usize, f64)> = Vec::new();
    let push = |out: &mut Vec<(Vec<u32>, StrategyKind, usize, f64)>,
                    rows: &[(Vec<u32>, f64)],
                    kind: StrategyKind,
                    quota: usize| {
        for (rank, (row, conf)) in rows.iter().enumerate() {
            if out.len() >= quota {
                break;
            }
            let trunc = &row[..row.len().min(w)];
            let exists = out.iter().any(|(t, _, _, _)| t == trunc);
            if !exists {
                let conf = conf.clamp(f64::MIN_POSITIVE, 1.0);
                out.push((trunc.to_vec(), kind, rank, conf));
            }
        }
    };
    push(&mut out, &ctx_rows, StrategyKind::ContextNgram, k);
    push(&mut out, &big_rows, StrategyKind::ExtendedBigram, k);
    out
}

#[test]
fn mixed_matches_seed_on_random_sequences_and_tables() {
    prop::check(250, |rng| {
        let vocab = rng.range(4, 24);
        let topk = rng.range(2, 8);
        let depth = rng.range(1, 6);
        let tables = random_tables(rng, vocab, topk, depth);
        let q = rng.range(1, 2);
        let w = rng.range(1, 8);
        let k = rng.range(1, 10);
        let len = rng.range(0, 80);
        let seq = prop::vec_u32(rng, len, 0..vocab as u32);
        let mut m = MixedStrategy::paper(tables.clone(), q);
        let mut b = DraftBatch::new(w);
        m.propose(&seq, k, &mut b);
        rows_of(&b) == seed_mixed_rows(&tables, q, &seq, k, w)
    });
}

#[test]
fn mixed_is_stable_across_repeated_proposals_on_one_instance() {
    // the persistent suffix index inside the mixed policy must not bleed
    // state between proposals: proposing twice on the same (or a grown)
    // sequence matches the stateless seed both times
    prop::check(120, |rng| {
        let vocab = rng.range(4, 16);
        let tables = random_tables(rng, vocab, 4, 3);
        let mut m = MixedStrategy::paper(tables.clone(), 1);
        let mut seq = prop::vec_u32(rng, rng.range(1, 40), 0..vocab as u32);
        for _ in 0..rng.range(2, 10) {
            let w = rng.range(1, 6);
            let k = rng.range(1, 8);
            let mut b = DraftBatch::new(w);
            m.propose(&seq, k, &mut b);
            if rows_of(&b) != seed_mixed_rows(&tables, 1, &seq, k, w) {
                return false;
            }
            if rng.f64() < 0.3 && !seq.is_empty() {
                let keep = rng.below(seq.len() + 1);
                seq.truncate(keep.max(1));
            }
            seq.push(rng.below(vocab) as u32);
        }
        true
    });
}
