//! Batched-engine integration: the continuous-batching engine must be
//! byte-identical to per-sequence decoding (the paper's invariant extended
//! across the request-batch axis), lanes must never cross-contaminate, and
//! packing must actually pay at the cost-model level.

use ngrammys::bench::BenchCtx;
use ngrammys::config::EngineConfig;
use ngrammys::engine::batched::generate_all;
use ngrammys::engine::{BatchedEngine, SpecDecoder};
use ngrammys::kvcache::KvPool;
use ngrammys::scheduler::{make_strategy, StrategyName};
use ngrammys::util::prop;
use ngrammys::util::rng::Rng;

fn ctx(model: &str) -> BenchCtx {
    BenchCtx::load(ngrammys::testkit::manifest(), model).unwrap()
}

fn prompts(c: &BenchCtx) -> Vec<Vec<u32>> {
    [
        "Question: Tom has 4 apples. Tom buys 2 more.",
        "def scale(x, y):\n    result",
        "User: What is the capital of France?",
        "Answer: Mia has 5 coins.",
        "def blend(value, count):",
        "User: Tell me about ancient rivers.",
        "Question: Sam has 7 cards.",
        "Assistant: That is a good question.",
    ]
    .iter()
    .map(|p| c.tokenizer.encode(p))
    .collect()
}

/// THE acceptance test: for the same prompts, the batched engine at
/// concurrency 1, 4 and 8 produces byte-identical token streams to the
/// single-sequence SpecDecoder, for mixed/context/none strategies.
#[test]
fn batched_streams_equal_per_sequence_streams() {
    let c = ctx("small");
    let prompts = prompts(&c);
    for (strat, k, w) in [
        (StrategyName::Mixed, 10, 10),
        (StrategyName::Context, 5, 4),
        (StrategyName::None, 1, 0),
    ] {
        let cfg = EngineConfig { k, w, q: 1, max_new_tokens: 20 };
        let want: Vec<Vec<u32>> = prompts
            .iter()
            .map(|p| {
                let s = make_strategy(strat, &c.tables, 1);
                let mut dec = SpecDecoder::new(&c.runtime, s, cfg.clone());
                dec.generate(p).unwrap().tokens
            })
            .collect();
        for conc in [1usize, 4, 8] {
            let reqs: Vec<_> = prompts
                .iter()
                .map(|p| (p.clone(), make_strategy(strat, &c.tables, 1), cfg.clone()))
                .collect();
            let mut eng = BatchedEngine::new(&c.runtime, conc);
            let got = generate_all(&mut eng, reqs).unwrap();
            for (i, (g, w_)) in got.iter().zip(&want).enumerate() {
                assert_eq!(
                    &g.tokens, w_,
                    "strategy {strat:?} conc {conc} prompt {i}: batched stream diverged"
                );
            }
        }
    }
}

/// Requests with DIFFERENT (k, w) configs share packed steps and still
/// all come back greedy-identical.
#[test]
fn heterogeneous_configs_share_a_batch_correctly() {
    let c = ctx("small");
    let prompts = prompts(&c);
    let cfgs = [
        EngineConfig { k: 10, w: 10, q: 1, max_new_tokens: 16 },
        EngineConfig { k: 5, w: 4, q: 1, max_new_tokens: 16 },
        EngineConfig { k: 2, w: 2, q: 1, max_new_tokens: 16 },
        EngineConfig { k: 1, w: 0, q: 1, max_new_tokens: 16 },
    ];
    let want: Vec<Vec<u32>> = prompts
        .iter()
        .zip(cfgs.iter().cycle())
        .map(|(p, cfg)| {
            let s = make_strategy(StrategyName::Mixed, &c.tables, 1);
            let mut dec = SpecDecoder::new(&c.runtime, s, cfg.clone());
            dec.generate(p).unwrap().tokens
        })
        .collect();
    let reqs: Vec<_> = prompts
        .iter()
        .zip(cfgs.iter().cycle())
        .map(|(p, cfg)| {
            (p.clone(), make_strategy(StrategyName::Mixed, &c.tables, 1), cfg.clone())
        })
        .collect();
    let mut eng = BatchedEngine::new(&c.runtime, 4);
    let got = generate_all(&mut eng, reqs).unwrap();
    for (i, (g, w_)) in got.iter().zip(&want).enumerate() {
        assert_eq!(&g.tokens, w_, "heterogeneous request {i} diverged");
    }
}

/// More requests than lanes: lanes must recycle and every request must
/// still complete, in order, with the pool fully reclaimed.
#[test]
fn lanes_recycle_across_admission_waves() {
    let c = ctx("small");
    let all = prompts(&c);
    let cfg = EngineConfig { k: 5, w: 4, q: 1, max_new_tokens: 10 };
    // 8 requests through 2 lanes -> at least 4 admission waves
    let mut eng = BatchedEngine::new(&c.runtime, 2);
    let mut next = 0usize;
    let mut done = 0usize;
    while done < all.len() {
        while eng.has_capacity() && next < all.len() {
            let s = make_strategy(StrategyName::Mixed, &c.tables, 1);
            eng.admit(&all[next], s, cfg.clone()).unwrap();
            next += 1;
        }
        assert!(eng.lanes_in_use() <= 2);
        for (_, r) in eng.step().unwrap() {
            assert_eq!(r.tokens.len(), 10);
            done += 1;
        }
    }
    assert_eq!(eng.active(), 0);
    assert_eq!(eng.lanes_in_use(), 0, "retired lanes must be reclaimed");
    // the freed pool admits again immediately
    let s = make_strategy(StrategyName::Mixed, &c.tables, 1);
    eng.admit(&all[0], s, cfg).unwrap();
    assert_eq!(eng.lanes_in_use(), 1);
}

/// Property: commits into one pool lane NEVER touch another lane's bytes
/// or length, for arbitrary shapes, lanes and interleavings.
#[test]
fn prop_lane_commits_never_cross_contaminate() {
    prop::check(150, |rng: &mut Rng| {
        let layers = rng.range(1, 3);
        let heads = rng.range(1, 3);
        let hd = 4usize;
        let max_len = rng.range(8, 24);
        let n_lanes = rng.range(2, 4);
        let mut pool = KvPool::new(layers, max_len, heads, hd, n_lanes);
        let lanes: Vec<_> = (0..n_lanes).map(|_| pool.acquire().unwrap()).collect();
        // give every lane a distinct fingerprint
        for (li, &lane) in lanes.iter().enumerate() {
            let c = pool.lane_mut(lane);
            for v in c.k_data.iter_mut() {
                *v = li as f32 + 100.0;
            }
            for v in c.v_data.iter_mut() {
                *v = -(li as f32) - 100.0;
            }
            c.len = rng.range(0, max_len / 2);
        }
        let mut snapshot: Vec<(Vec<f32>, Vec<f32>, usize)> = lanes
            .iter()
            .map(|&l| (pool.lane(l).k_data.clone(), pool.lane(l).v_data.clone(), pool.lane(l).len))
            .collect();

        // random interleaved commits
        for _ in 0..rng.range(1, 8) {
            let target = rng.below(n_lanes);
            let lane = lanes[target];
            let ps = pool.lane(lane).pos_stride();
            let k_rows = rng.range(1, 3);
            let w1 = rng.range(1, 3);
            let room = max_len - pool.lane(lane).len;
            if room < w1 {
                continue;
            }
            let n = layers * k_rows * w1 * ps;
            let k_tail: Vec<f32> = (0..n).map(|i| 1000.0 + target as f32 + i as f32).collect();
            let v_tail: Vec<f32> = (0..n).map(|i| -(1000.0 + target as f32 + i as f32)).collect();
            let row = rng.below(k_rows);
            let count = rng.range(1, w1);
            pool.lane_mut(lane)
                .commit_tail(&k_tail, &v_tail, k_rows, w1, row, count)
                .unwrap();
            // every OTHER lane must be bit-identical to its snapshot
            for (li, &other) in lanes.iter().enumerate() {
                if li == target {
                    continue;
                }
                let (k0, v0, len0) = &snapshot[li];
                let c = pool.lane(other);
                if &c.k_data != k0 || &c.v_data != v0 || c.len != *len0 {
                    return false;
                }
            }
            // refresh the committed lane's snapshot for later iterations
            let c = pool.lane(lane);
            snapshot[target] = (c.k_data.clone(), c.v_data.clone(), c.len);
        }
        true
    });
}

/// The point of packing: at concurrency 4+, the cost model prices the
/// batched engine's packed calls well below the per-sequence calls they
/// replace — higher aggregate simulated tokens/sec than request-batch 1.
#[test]
fn packed_calls_beat_request_batch_1_on_the_cost_model() {
    let c = ctx("base");
    let prompts = prompts(&c);
    let cfg = EngineConfig { k: 10, w: 10, q: 1, max_new_tokens: 16 };
    let cm = c.cost_model();

    // request-batch-1 baseline
    let mut seq_tokens = 0usize;
    let mut seq_sim = 0.0f64;
    for p in &prompts {
        let s = make_strategy(StrategyName::Mixed, &c.tables, 1);
        let mut dec = SpecDecoder::new(&c.runtime, s, cfg.clone());
        dec.collect_traces = true;
        let r = dec.generate(p).unwrap();
        seq_tokens += r.tokens.len() - 1;
        seq_sim += r
            .traces
            .iter()
            .map(|t| cm.call_time(t.k, t.w + 1, t.ctx_len))
            .sum::<f64>();
    }

    // batched engine at concurrency 4
    let mut eng = BatchedEngine::new(&c.runtime, 4);
    eng.collect_traces = true;
    let reqs: Vec<_> = prompts
        .iter()
        .map(|p| (p.clone(), make_strategy(StrategyName::Mixed, &c.tables, 1), cfg.clone()))
        .collect();
    let bat_results = generate_all(&mut eng, reqs).unwrap();
    let bat_tokens: usize = bat_results.iter().map(|r| r.tokens.len() - 1).sum();
    assert_eq!(bat_tokens, seq_tokens, "token accounting diverged");
    let bat_sim: f64 = eng
        .packed_traces
        .iter()
        .map(|p| cm.call_time(p.rows, p.w + 1, p.max_ctx))
        .sum();

    let seq_tps = seq_tokens as f64 / seq_sim;
    let bat_tps = bat_tokens as f64 / bat_sim;
    assert!(
        bat_tps > seq_tps * 1.3,
        "batched sim throughput {bat_tps:.1} tok/s not clearly above \
         request-batch-1 {seq_tps:.1} tok/s"
    );
}
