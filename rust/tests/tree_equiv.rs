//! Differential oracle for tree speculation: the token-tree verifier must
//! be byte-identical to flat-row speculation AND to plain greedy decoding
//! — per sequence, across the request-batch axis at concurrency 1/4/8,
//! over randomized trajectories, and under an adversarial strategy whose
//! drafts are wrong on purpose so every step exercises the zero-accept
//! rollback path (KV truncation back to the committed prefix).
//!
//! The linear SpecDecoder is itself pinned byte-identical to greedy by the
//! engine tests, so any divergence here isolates to the tree path: trie
//! packing, ancestor-masked verification, the root-to-leaf judge, or the
//! tree commit/rollback.

use ngrammys::bench::BenchCtx;
use ngrammys::config::EngineConfig;
use ngrammys::draft::{DraftBatch, DraftStrategy, StrategyKind};
use ngrammys::engine::batched::generate_all;
use ngrammys::engine::{greedy_config, BatchedEngine, SpecDecoder};
use ngrammys::scheduler::{make_strategy, StrategyName};
use ngrammys::tokenizer::TokenId;
use ngrammys::util::prop;
use ngrammys::util::rng::Rng;

fn ctx(model: &str) -> BenchCtx {
    BenchCtx::load(ngrammys::testkit::manifest(), model).unwrap()
}

fn prompts(c: &BenchCtx) -> Vec<Vec<u32>> {
    [
        "Question: Tom has 4 apples. Tom buys 2 more.",
        "def scale(x, y):\n    result",
        "User: What is the capital of France?",
        "Answer: Mia has 5 coins.",
        "def blend(value, count):",
        "User: Tell me about ancient rivers.",
        "Question: Sam has 7 cards.",
        "Assistant: That is a good question.",
    ]
    .iter()
    .map(|p| c.tokenizer.encode(p))
    .collect()
}

/// THE acceptance test: for the same prompts, tree-mode decoding — both
/// the single-sequence SpecDecoder and the batched engine at concurrency
/// 1, 4 and 8 — produces byte-identical token streams to flat-row
/// speculation, for mixed/context strategies across block shapes.
#[test]
fn tree_streams_equal_linear_and_per_sequence_streams() {
    let c = ctx("small");
    let prompts = prompts(&c);
    for (strat, k, w) in [
        (StrategyName::Mixed, 10, 10),
        (StrategyName::Mixed, 2, 2),
        (StrategyName::Context, 5, 4),
    ] {
        let cfg = EngineConfig { k, w, q: 1, max_new_tokens: 20 };
        // oracle: the linear (flat-row) per-sequence decoder
        let want: Vec<Vec<u32>> = prompts
            .iter()
            .map(|p| {
                let s = make_strategy(strat, &c.tables, 1);
                let mut dec = SpecDecoder::new(&c.runtime, s, cfg.clone());
                dec.generate(p).unwrap().tokens
            })
            .collect();
        // tree-mode per-sequence decoder
        for (i, p) in prompts.iter().enumerate() {
            let s = make_strategy(strat, &c.tables, 1);
            let mut dec = SpecDecoder::new(&c.runtime, s, cfg.clone());
            dec.tree = true;
            assert_eq!(
                dec.generate(p).unwrap().tokens,
                want[i],
                "strategy {strat:?} k={k} w={w} prompt {i}: tree SpecDecoder diverged"
            );
        }
        // tree-mode batched engine, across the concurrency axis
        for conc in [1usize, 4, 8] {
            let reqs: Vec<_> = prompts
                .iter()
                .map(|p| (p.clone(), make_strategy(strat, &c.tables, 1), cfg.clone()))
                .collect();
            let mut eng = BatchedEngine::new(&c.runtime, conc);
            eng.tree = true;
            let got = generate_all(&mut eng, reqs).unwrap();
            for (i, (g, w_)) in got.iter().zip(&want).enumerate() {
                assert_eq!(
                    &g.tokens, w_,
                    "strategy {strat:?} conc {conc} prompt {i}: batched tree stream diverged"
                );
            }
        }
    }
}

/// Drafts that are wrong on purpose: every proposal is `k` rows of tokens
/// derived from the anchor by fixed offsets, so verification rejects
/// (almost) everything and every step takes the zero-accept rollback path
/// mid-stream — the tree commits only the bonus token and truncates the
/// speculated KV tail.
struct JunkDraft {
    vocab: usize,
}

impl DraftStrategy for JunkDraft {
    fn name(&self) -> &'static str {
        "junk"
    }

    fn propose(&mut self, seq: &[TokenId], k: usize, batch: &mut DraftBatch) {
        let last = *seq.last().unwrap() as usize;
        for r in 0..k {
            // 12 tokens: longer than any test w, the batch truncates
            let row: Vec<TokenId> = (0..12)
                .map(|j| ((last + 1 + 7 * r + 3 * j) % self.vocab) as TokenId)
                .collect();
            batch.push(row, StrategyKind::Jacobi, r);
        }
    }
}

/// Adversarial rollback coverage: with junk drafts the tree stream must
/// STILL be byte-identical to greedy (all-junk lanes, and junk lanes
/// packed next to productive mixed lanes in the same grouped calls), and
/// the junk run's acceptance must be near zero — proving the rollback
/// path actually ran on essentially every step.
#[test]
fn junk_drafts_roll_back_and_stay_greedy_identical() {
    let c = ctx("small");
    let prompts = prompts(&c);
    let vocab = c.manifest.vocab_size;
    let max_new = 24usize;
    let cfg = EngineConfig { k: 5, w: 4, q: 1, max_new_tokens: max_new };
    let want: Vec<Vec<u32>> = prompts
        .iter()
        .map(|p| {
            let s = make_strategy(StrategyName::None, &c.tables, 1);
            let mut dec = SpecDecoder::new(&c.runtime, s, greedy_config(max_new));
            dec.generate(p).unwrap().tokens
        })
        .collect();

    // every lane junk: zero-accept rollback on (almost) every call
    let reqs: Vec<_> = prompts
        .iter()
        .map(|p| {
            let junk: Box<dyn DraftStrategy> = Box::new(JunkDraft { vocab });
            (p.clone(), junk, cfg.clone())
        })
        .collect();
    let mut eng = BatchedEngine::new(&c.runtime, 4);
    eng.tree = true;
    let got = generate_all(&mut eng, reqs).unwrap();
    let mut decode_tokens = 0usize;
    let mut calls = 0usize;
    for (i, (g, w_)) in got.iter().zip(&want).enumerate() {
        assert_eq!(&g.tokens, w_, "junk lane {i}: tree stream diverged from greedy");
        decode_tokens += g.tokens.len() - 1;
        calls += g.calls;
    }
    // junk never helps: each call emits ~1 bonus token, so tokens/call
    // stays near 1 (a loose 1.25 bound tolerates lucky collisions)
    assert!(
        (decode_tokens as f64) < 1.25 * calls as f64,
        "junk drafts were accepted too often ({decode_tokens} tokens / {calls} calls) — \
         the rollback path was not exercised"
    );

    // junk and mixed lanes packed into the SAME grouped tree calls:
    // rolling lanes must not disturb accepting ones (and vice versa)
    let reqs: Vec<_> = prompts
        .iter()
        .enumerate()
        .map(|(i, p)| {
            let s: Box<dyn DraftStrategy> = if i % 2 == 0 {
                Box::new(JunkDraft { vocab })
            } else {
                make_strategy(StrategyName::Mixed, &c.tables, 1)
            };
            (p.clone(), s, cfg.clone())
        })
        .collect();
    let mut eng = BatchedEngine::new(&c.runtime, 4);
    eng.tree = true;
    let got = generate_all(&mut eng, reqs).unwrap();
    for (i, (g, w_)) in got.iter().zip(&want).enumerate() {
        assert_eq!(&g.tokens, w_, "mixed/junk lane {i}: tree stream diverged from greedy");
    }
}

/// Property: over randomized trajectories — repetition-heavy prompts,
/// arbitrary block shapes (k, w), concurrency and generation lengths —
/// the batched tree engine's streams equal plain greedy decoding.
#[test]
fn prop_random_trajectories_stay_greedy_identical() {
    let c = ctx("small");
    let vocab = c.manifest.vocab_size;
    prop::check(12, |rng: &mut Rng| {
        let k = rng.range(1, 6);
        let w = rng.range(1, 6);
        let conc = rng.range(1, 4);
        let max_new = rng.range(8, 20);
        let n_prompts = rng.range(2, 4);
        let prompts: Vec<Vec<u32>> = (0..n_prompts)
            .map(|_| {
                // a short random motif repeated with occasional noise, so
                // the context source finds matches and the tree branches
                let motif = prop::vec_u32(rng, rng.range(3, 8), 0..vocab as u32);
                let mut p = Vec::new();
                for _ in 0..rng.range(2, 6) {
                    p.extend_from_slice(&motif);
                    if rng.f64() < 0.5 {
                        p.push(rng.below(vocab) as u32);
                    }
                }
                p
            })
            .collect();
        let cfg = EngineConfig { k, w, q: 1, max_new_tokens: max_new };
        let want: Vec<Vec<u32>> = prompts
            .iter()
            .map(|p| {
                let s = make_strategy(StrategyName::None, &c.tables, 1);
                let mut dec = SpecDecoder::new(&c.runtime, s, greedy_config(max_new));
                dec.generate(p).unwrap().tokens
            })
            .collect();
        let reqs: Vec<_> = prompts
            .iter()
            .map(|p| (p.clone(), make_strategy(StrategyName::Mixed, &c.tables, 1), cfg.clone()))
            .collect();
        let mut eng = BatchedEngine::new(&c.runtime, conc);
        eng.tree = true;
        let got = generate_all(&mut eng, reqs).unwrap();
        got.iter().zip(&want).all(|(g, w_)| &g.tokens == w_)
    });
}
