//! Property tests for the [`DraftTree`] arena: structural invariants that
//! the packed tree verifier and the root-to-leaf judge silently rely on,
//! checked against naive oracles over randomized insert/truncate/reset
//! trajectories.
//!
//! - **Parent-pointer well-formedness**: node 0 is the root
//!   (`NO_PARENT`), every other node's parent has a strictly lower index
//!   (ascending index order IS topological order), and depths are exactly
//!   parent depth + 1, capped at the block depth `w`.
//! - **Sibling distinctness**: no two children of one parent speculate
//!   the same token — including after mid-trajectory `truncate` rollback
//!   re-inserts rows over the surviving prefix (stale-child aliasing is
//!   what this pins).
//! - **Ancestor masks**: every node's stored mask equals an O(n^2) oracle
//!   that re-walks the parent chain bit by bit.
//! - **Trie semantics**: inserted rows stay traversable root-to-leaf via
//!   `child_matching`, duplicates create nothing, and the arena never
//!   exceeds the `k * (w + 1)` node budget.

use ngrammys::draft::tree::NO_PARENT;
use ngrammys::draft::{DraftTree, StrategyKind};
use ngrammys::util::prop;
use ngrammys::util::rng::Rng;

/// O(parent-chain) recomputation of node `i`'s self-inclusive ancestor
/// mask, independent of the arena's incremental copy-on-push scheme.
fn naive_mask(t: &DraftTree, i: usize) -> Vec<u64> {
    let mut m = vec![0u64; t.words()];
    let mut cur = i;
    loop {
        m[cur / 64] |= 1u64 << (cur % 64);
        let p = t.parents()[cur];
        if p == NO_PARENT {
            break;
        }
        cur = p as usize;
    }
    m
}

/// Every structural invariant the verifier and judge depend on; `w` is
/// the block depth fixed by the last `reset`.
fn invariants_hold(t: &DraftTree, w: usize) -> bool {
    let n = t.len();
    if n == 0 || n > t.budget() {
        return false;
    }
    let parents = t.parents();
    if parents[0] != NO_PARENT || t.depth(0) != 0 {
        return false;
    }
    for i in 1..n {
        let p = parents[i];
        // parents strictly precede children (topological index order)
        if p == NO_PARENT || p as usize >= i {
            return false;
        }
        if t.depth(i) != t.depth(p as usize) + 1 || t.depth(i) > w {
            return false;
        }
        // sibling distinctness: i must be ITS OWN first match under its
        // parent — an earlier sibling with the same token is aliasing
        if t.child_matching(p, t.token(i)) != Some(i as u32) {
            return false;
        }
    }
    // stored masks equal the naive parent-chain oracle
    for i in 0..n {
        if t.mask(i) != naive_mask(t, i).as_slice() {
            return false;
        }
    }
    // leaf count against an independent has-child scan
    let mut has_child = vec![false; n];
    for &p in &parents[1..n] {
        has_child[p as usize] = true;
    }
    let leaves = has_child.iter().filter(|&&h| !h).count();
    t.leaf_count() == leaves
}

/// Random insert/truncate/reset trajectories over a tiny alphabet (so
/// prefixes really collide) preserve every arena invariant, and
/// `insert_row`'s return value exactly accounts for arena growth.
#[test]
fn prop_trajectories_preserve_arena_invariants() {
    prop::check(300, |rng: &mut Rng| {
        let mut t = DraftTree::new();
        let mut k = rng.range(1, 6);
        let mut w = rng.range(1, 6);
        // tiny alphabet: forces shared prefixes and sibling collisions
        let alphabet = rng.range(2, 5);
        t.reset(rng.below(64) as u32, k, w);
        for _ in 0..rng.range(1, 40) {
            match rng.below(8) {
                0 => {
                    // rollback: drop an arbitrary suffix (clamped to root)
                    t.truncate(rng.range(0, t.len() + 1));
                }
                1 => {
                    // re-root with a fresh shape
                    k = rng.range(1, 6);
                    w = rng.range(1, 6);
                    t.reset(rng.below(64) as u32, k, w);
                }
                _ => {
                    // insert a random row (possibly empty or beyond w)
                    let len = rng.range(0, w + 2);
                    let row: Vec<u32> =
                        (0..len).map(|_| rng.below(alphabet) as u32).collect();
                    let before = t.len();
                    let created =
                        t.insert_row(&row, StrategyKind::ContextNgram, rng.below(4), rng.below(k));
                    if t.len() != before + created {
                        return false;
                    }
                }
            }
            if !invariants_hold(&t, w) {
                return false;
            }
        }
        true
    });
}

/// Without budget pressure (`k` = row count, so `k * (w + 1)` always
/// fits), every inserted row stays traversable root-to-leaf through
/// `child_matching`, and re-inserting the same rows creates nothing.
#[test]
fn prop_inserted_rows_are_traversable_paths() {
    prop::check(200, |rng: &mut Rng| {
        let w = rng.range(1, 6);
        let n_rows = rng.range(1, 6);
        let alphabet = rng.range(2, 6);
        let rows: Vec<Vec<u32>> = (0..n_rows)
            .map(|_| (0..rng.range(1, w)).map(|_| rng.below(alphabet) as u32).collect())
            .collect();
        let mut t = DraftTree::new();
        t.reset(rng.below(64) as u32, n_rows, w);
        for (r, row) in rows.iter().enumerate() {
            t.insert_row(row, StrategyKind::ContextNgram, 0, r);
        }
        let walkable = |row: &Vec<u32>| {
            let mut cur = 0u32;
            row.iter().take(w).all(|&tok| match t.child_matching(cur, tok) {
                Some(c) => {
                    cur = c;
                    true
                }
                None => false,
            })
        };
        if !rows.iter().all(walkable) {
            return false;
        }
        // duplicates are free: a second pass over the same rows is a no-op
        let before = t.len();
        for (r, row) in rows.iter().enumerate() {
            if t.insert_row(row, StrategyKind::ContextNgram, 0, r) != 0 {
                return false;
            }
        }
        t.len() == before && invariants_hold(&t, w)
    });
}

/// Rollback then re-insert: truncating to an arbitrary prefix and
/// replaying the original rows rebuilds a well-formed trie — surviving
/// prefix nodes are reused (no sibling aliasing from stale children) and
/// every row is traversable again.
#[test]
fn prop_truncate_then_reinsert_reuses_surviving_prefix() {
    prop::check(200, |rng: &mut Rng| {
        let w = rng.range(1, 5);
        let n_rows = rng.range(2, 6);
        let alphabet = rng.range(2, 4);
        let rows: Vec<Vec<u32>> = (0..n_rows)
            .map(|_| (0..w).map(|_| rng.below(alphabet) as u32).collect())
            .collect();
        let mut t = DraftTree::new();
        t.reset(0, n_rows, w);
        for (r, row) in rows.iter().enumerate() {
            t.insert_row(row, StrategyKind::ContextNgram, 0, r);
        }
        let full = t.len();
        t.truncate(rng.range(1, full));
        for (r, row) in rows.iter().enumerate() {
            t.insert_row(row, StrategyKind::ContextNgram, 0, r);
        }
        // the rebuilt trie holds exactly the original node set's shape:
        // same size, same invariants, all rows walkable
        if t.len() != full || !invariants_hold(&t, w) {
            return false;
        }
        rows.iter().all(|row| {
            let mut cur = 0u32;
            row.iter().all(|&tok| match t.child_matching(cur, tok) {
                Some(c) => {
                    cur = c;
                    true
                }
                None => false,
            })
        })
    });
}
