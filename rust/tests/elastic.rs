//! Elastic-serving invariants: lane autoscaling (grow AND shrink, mid
//! flight), the online-derived row budget, and cost-aware admission
//! ordering must never change a single output byte — every stream stays
//! exactly the per-sequence greedy continuation of its prompt — while
//! the derived budget bound holds step by step.

use std::collections::HashMap;

use ngrammys::adaptive;
use ngrammys::bench::BenchCtx;
use ngrammys::config::{EngineConfig, ServeConfig, SessionCacheConfig};
use ngrammys::costmodel::CostModel;
use ngrammys::engine::{greedy_config, AutoBudget, BatchedEngine, NoDraft, SeqId, SpecDecoder};
use ngrammys::scheduler::{make_strategy, GenRequest, Scheduler, StrategyName};
use ngrammys::util::rng::Rng;

fn ctx(model: &str) -> BenchCtx {
    BenchCtx::load(ngrammys::testkit::manifest(), model).unwrap()
}

fn prompts(c: &BenchCtx) -> Vec<Vec<u32>> {
    [
        "Question: Tom has 4 apples. Tom buys 2 more.",
        "def scale(x, y):\n    result",
        "User: What is the capital of France?",
        "Answer: Mia has 5 coins.",
        "def blend(value, count):",
        "User: Tell me about ancient rivers.",
        "Question: Sam has 7 cards.",
        "Assistant: That is a good question.",
    ]
    .iter()
    .map(|p| c.tokenizer.encode(p))
    .collect()
}

fn greedy_stream(c: &BenchCtx, prompt: &[u32], max_new: usize) -> Vec<u32> {
    let mut dec = SpecDecoder::new(&c.runtime, Box::new(NoDraft), greedy_config(max_new));
    dec.generate(prompt).unwrap().tokens
}

fn auto_budget(c: &BenchCtx) -> AutoBudget {
    AutoBudget::new(CostModel::for_analog(&c.runtime.artifacts().dims.analog))
}

/// Random scale-up/scale-down trajectories at lane caps 1/4/8, with the
/// derived budget on and a mixed adaptive/static population: streams are
/// byte-identical to greedy, every step's packed rows respect that
/// step's derived budget, and a shrink never evicts a busy lane.
#[test]
fn autoscaling_is_lossless_and_budget_bounded() {
    let c = ctx("small");
    let max_new = 20;
    let ps = prompts(&c);
    let want: Vec<Vec<u32>> = ps.iter().map(|p| greedy_stream(&c, p, max_new)).collect();
    let cfg = EngineConfig { k: 10, w: 10, q: 1, max_new_tokens: max_new };
    let cache = SessionCacheConfig::default();
    let analog = c.runtime.artifacts().dims.analog.clone();

    for cap in [1usize, 4, 8] {
        let mut rng = Rng::new(0xE1A5 + cap as u64);
        let mut eng = BatchedEngine::new(&c.runtime, 1);
        eng.collect_traces = true;
        eng.auto_budget = Some(auto_budget(&c));
        let mut by_id: HashMap<SeqId, usize> = HashMap::new();
        let mut results: Vec<Option<Vec<u32>>> = vec![None; ps.len()];
        let mut next = 0usize;
        let mut done = 0usize;
        while done < ps.len() {
            // adversarial autoscaler: a random target every iteration
            let target = 1 + rng.below(cap);
            let achieved = eng.set_capacity(target);
            assert!(achieved >= eng.lanes_in_use(), "shrink evicted a busy lane");
            assert!(achieved <= cap, "capacity {achieved} above cap {cap}");
            while eng.has_capacity() && next < ps.len() {
                let id = if next % 2 == 0 {
                    let ctrl = adaptive::controller_for(&c.tables, 1, &cache, &analog);
                    eng.admit_with(
                        &ps[next],
                        make_strategy(StrategyName::Mixed, &c.tables, 1),
                        Some(ctrl),
                        cfg.clone(),
                    )
                    .unwrap()
                } else {
                    eng.admit(
                        &ps[next],
                        make_strategy(StrategyName::Mixed, &c.tables, 1),
                        cfg.clone(),
                    )
                    .unwrap()
                };
                by_id.insert(id, next);
                next += 1;
            }
            let active_before = eng.active();
            let trace_mark = eng.packed_traces.len();
            for (id, r) in eng.step().unwrap() {
                results[by_id[&id]] = Some(r.tokens);
                done += 1;
            }
            let step_rows: usize = eng.packed_traces[trace_mark..].iter().map(|t| t.rows).sum();
            let budget = eng
                .last_step_budget()
                .expect("auto-budget engine must report its step budget");
            assert!(
                step_rows <= budget.max(active_before),
                "cap {cap}: step packed {step_rows} rows > derived budget {budget} \
                 (active {active_before})"
            );
        }
        for (i, got) in results.iter().enumerate() {
            assert_eq!(
                got.as_ref().unwrap(),
                &want[i],
                "cap {cap} prompt {i}: stream diverged under autoscaling"
            );
        }
        // guaranteed scale-down exercise: once drained, a shrink to one
        // lane must fully succeed regardless of the random trajectory
        assert_eq!(eng.set_capacity(1), 1, "cap {cap}: drained pool refused to shrink");
    }
}

/// After the population drains, repeated downscale requests converge to
/// one lane — busy lanes only defer the shrink, never block it forever.
#[test]
fn shrink_converges_after_drain() {
    let c = ctx("small");
    let ps = prompts(&c);
    let cfg = EngineConfig { k: 5, w: 4, q: 1, max_new_tokens: 12 };
    let mut eng = BatchedEngine::new(&c.runtime, 6);
    for p in ps.iter().take(6) {
        eng.admit(p, make_strategy(StrategyName::Mixed, &c.tables, 1), cfg.clone())
            .unwrap();
    }
    assert_eq!(eng.capacity(), 6);
    // mid-flight downscale: bounded by busy lanes now...
    let mid = eng.set_capacity(1);
    assert!(mid >= eng.lanes_in_use());
    // ...but once everything retires, the next request lands
    while eng.active() > 0 {
        eng.step().unwrap();
        eng.set_capacity(1);
    }
    assert_eq!(eng.set_capacity(1), 1);
}

/// The full elastic scheduler (autoscaler + derived budget + scored
/// admission, `elastic: true` default) returns exactly the per-sequence
/// scheduler's streams and populates the elastic gauges.
#[test]
fn elastic_scheduler_matches_per_sequence_streams() {
    let m = ngrammys::testkit::manifest();
    let tok = ngrammys::tokenizer::BpeTokenizer::load(&m.tokenizer_path).unwrap();
    let texts = [
        "Question: Tom has 3 apples.",
        "def scale(x, y):",
        "User: What is the capital of France?",
        "Answer: Mia has 5 coins.",
        "Question: Sam has 7 cards.",
        "def blend(value, count):",
    ];
    let req = |p: &str, greedy: bool| GenRequest {
        prompt: tok.encode(p),
        engine: EngineConfig {
            k: if greedy { 1 } else { 5 },
            w: if greedy { 0 } else { 4 },
            q: 1,
            max_new_tokens: 12,
        },
        strategy: if greedy { StrategyName::None } else { StrategyName::Mixed },
    };
    let base_cfg = ServeConfig {
        addr: "127.0.0.1:0".into(),
        workers: 1,
        queue_cap: 16,
        ..ServeConfig::default()
    };

    let seq_sched = Scheduler::start(&m, "small", &base_cfg).unwrap();
    let want: Vec<Vec<u32>> = texts
        .iter()
        .enumerate()
        .map(|(i, p)| seq_sched.generate(req(p, i % 3 == 2)).unwrap().tokens)
        .collect();
    seq_sched.shutdown();

    let mut cfg = base_cfg;
    cfg.batch = 4;
    assert!(cfg.elastic, "elastic must be the batched-mode default");
    let sched = Scheduler::start(&m, "small", &cfg).unwrap();
    // submit everything at once: the pool must scale up from min_lanes,
    // admissions get reordered by score, and the budget is derived
    let rxs: Vec<_> = texts
        .iter()
        .enumerate()
        .map(|(i, p)| sched.submit(req(p, i % 3 == 2)).unwrap())
        .collect();
    for (rx, want) in rxs.into_iter().zip(&want) {
        let got = rx.recv().unwrap().unwrap();
        assert_eq!(&got.tokens, want, "elastic scheduler altered a stream");
    }
    let ord = std::sync::atomic::Ordering::Relaxed;
    assert!(sched.metrics.lanes.load(ord) >= 1, "lanes gauge never set");
    assert!(sched.metrics.lanes_target.load(ord) >= 1);
    assert!(
        sched.metrics.derived_budget.load(ord) >= 1,
        "derived budget gauge never set"
    );
    let rendered = sched.metrics.render();
    assert!(rendered.contains("ngrammys_lanes "));
    assert!(rendered.contains("ngrammys_derived_budget "));
    sched.shutdown();
}
