//! Steady-state allocation-count regression test for the draft hot path
//! (counting global allocator).
//!
//! The arena-backed `DraftBatch` + incremental suffix index exist so that
//! a steady-state decode step performs ZERO draft-side heap allocations —
//! the seed code instead rebuilt a window `HashMap` and cloned a `Vec`
//! per row on every step of every lane. This test pins that down:
//!
//! - **Fixed sequence** (a lane proposing repeatedly at one context):
//!   every strategy must allocate EXACTLY 0 times per proposal once warm,
//!   including the arena writes and the assembled-block copy.
//! - **Appending stream** (tokens accepted between proposals): the only
//!   permitted allocations are the amortised growth of the suffix index's
//!   own storage (posting lists and its sequence copy double as they
//!   grow), bounded well under one allocation per step — the seed did
//!   dozens PER step. Table strategies must stay at exactly 0.
//! - **Tree packing** (branching enabled): overdraft proposal plus trie
//!   insertion into the `DraftTree` arena — node descriptors, parent
//!   pointers and ancestor masks — must also be EXACTLY 0 once warm.
//!
//! Kept as its own test binary with a single #[test] so no concurrent
//! test pollutes the counter.

use std::alloc::{GlobalAlloc, Layout, System};
use std::sync::atomic::{AtomicBool, AtomicU64, Ordering};
use std::sync::Arc;

use ngrammys::draft::tables::Table;
use ngrammys::draft::{
    ContextNgram, DraftBatch, DraftStrategy, DraftTree, ExtendedBigram, JacobiDraft,
    MixedStrategy, ModelBigram, ModelUnigram, NgramTables, SessionNgramCache,
};

struct CountingAlloc;

static ARMED: AtomicBool = AtomicBool::new(false);
static ALLOCS: AtomicU64 = AtomicU64::new(0);

unsafe impl GlobalAlloc for CountingAlloc {
    unsafe fn alloc(&self, layout: Layout) -> *mut u8 {
        if ARMED.load(Ordering::Relaxed) {
            ALLOCS.fetch_add(1, Ordering::Relaxed);
        }
        System.alloc(layout)
    }

    unsafe fn dealloc(&self, ptr: *mut u8, layout: Layout) {
        System.dealloc(ptr, layout)
    }

    unsafe fn realloc(&self, ptr: *mut u8, layout: Layout, new_size: usize) -> *mut u8 {
        if ARMED.load(Ordering::Relaxed) {
            ALLOCS.fetch_add(1, Ordering::Relaxed);
        }
        System.realloc(ptr, layout, new_size)
    }

    unsafe fn alloc_zeroed(&self, layout: Layout) -> *mut u8 {
        if ARMED.load(Ordering::Relaxed) {
            ALLOCS.fetch_add(1, Ordering::Relaxed);
        }
        System.alloc_zeroed(layout)
    }
}

#[global_allocator]
static GLOBAL: CountingAlloc = CountingAlloc;

/// Heap allocations performed while running `f`.
fn count_allocs(f: impl FnOnce()) -> u64 {
    ALLOCS.store(0, Ordering::SeqCst);
    ARMED.store(true, Ordering::SeqCst);
    f();
    ARMED.store(false, Ordering::SeqCst);
    ALLOCS.load(Ordering::SeqCst)
}

const VOCAB: u32 = 64;
const PERIOD: usize = 24;
const K: usize = 10;
const W: usize = 10;

fn cyclic_token(i: usize) -> u32 {
    // period-PERIOD stream with a fixed phrase structure: plenty of
    // repeated n-grams for the context/session strategies to match
    ((i % PERIOD) as u32 * 7 + 3) % VOCAB
}

fn synthetic_tables() -> Arc<NgramTables> {
    let vocab = VOCAB as usize;
    let topk = 8usize;
    let depth = 8usize;
    let bigram = Table::from_data(
        vocab,
        topk,
        1,
        (0..VOCAB)
            .flat_map(|x| (1..=topk as u32).map(move |j| (x + j) % VOCAB))
            .collect(),
    );
    let unigram = Table::from_data(1, topk, 1, (0..topk as u32).collect());
    let ext = Table::from_data(
        vocab,
        topk,
        depth,
        (0..VOCAB)
            .flat_map(|x| {
                (1..=topk as u32)
                    .flat_map(move |j| (0..depth as u32).map(move |d| (x + j + d) % VOCAB))
            })
            .collect(),
    );
    Arc::new(NgramTables { bigram, unigram, ext_bigram: ext })
}

/// Emulates the engine's block assembly off the batch arena into the
/// reused buffer (`engine::assemble_block_into`'s copy pattern).
fn assemble_into(batch: &DraftBatch, anchor: u32, w: usize, out: &mut Vec<u32>) {
    out.clear();
    for r in 0..batch.k() {
        out.push(anchor);
        let toks = batch.row_tokens(r);
        out.extend_from_slice(toks);
        for _ in toks.len()..w {
            out.push(anchor);
        }
    }
}

#[test]
fn steady_state_draft_step_does_not_allocate() {
    let tables = synthetic_tables();
    let mut strategies: Vec<(&str, Box<dyn DraftStrategy>, bool)> = vec![
        // (label, strategy, uses a growing index -> amortised budget)
        ("context-ngram", Box::new(ContextNgram::new(1)), true),
        ("mixed", Box::new(MixedStrategy::paper(tables.clone(), 1)), true),
        ("ext-bigram", Box::new(ExtendedBigram::new(tables.clone())), false),
        ("model-bigram", Box::new(ModelBigram::new(tables.clone())), false),
        ("model-unigram", Box::new(ModelUnigram::new(tables.clone())), false),
        ("session-cache", Box::new(SessionNgramCache::new(8, 8, 100_000)), false),
        ("jacobi", Box::new(JacobiDraft::new(0)), false),
    ];

    let warm_len = 512usize;
    let measure_steps = 128usize;
    let mut seq: Vec<u32> = (0..warm_len).map(cyclic_token).collect();
    // the stream itself is test harness state, not draft state: reserve
    // up front so its growth never hits the counter
    seq.reserve(measure_steps * 2 + 8);

    let mut batch = DraftBatch::new(W);
    let mut block: Vec<u32> = Vec::new();
    let model_out: Vec<u32> = (0..W as u32 + 1).map(|i| cyclic_token(i as usize)).collect();

    // --- warm every strategy: propose/observe over the whole stream so
    // arenas, scratch, posting lists and the session table saturate
    for (_, s, _) in strategies.iter_mut() {
        for end in (PERIOD * 2..warm_len).step_by(2) {
            batch.reset(W);
            s.propose(&seq[..end], K, &mut batch);
            assemble_into(&batch, seq[end - 1], W, &mut block);
            s.observe(&seq[end..(end + 2).min(warm_len)], &model_out);
        }
    }

    // --- phase 1: fixed sequence — EXACTLY zero allocations per step for
    // every strategy (proposal + arena writes + block assembly)
    for (label, s, _) in strategies.iter_mut() {
        // one unarmed iteration so any capacity nudged by the final warm
        // shape settles
        batch.reset(W);
        s.propose(&seq, K, &mut batch);
        assemble_into(&batch, seq[warm_len - 1], W, &mut block);

        let n = count_allocs(|| {
            for _ in 0..measure_steps {
                batch.reset(W);
                s.propose(&seq, K, &mut batch);
                assemble_into(&batch, seq[warm_len - 1], W, &mut block);
            }
        });
        assert_eq!(
            n, 0,
            "{label}: fixed-sequence steady state must be allocation-free \
             ({n} allocations over {measure_steps} steps)"
        );
    }

    // --- phase 2: appending stream — index growth is the only permitted
    // allocation source, amortised well under one per step; strategies
    // without a growing index stay at exactly zero
    for (label, s, has_index) in strategies.iter_mut() {
        let base_len = seq.len();
        let n = count_allocs(|| {
            for i in 0..measure_steps {
                seq.push(cyclic_token(base_len + 2 * i));
                seq.push(cyclic_token(base_len + 2 * i + 1));
                batch.reset(W);
                s.propose(&seq, K, &mut batch);
                assemble_into(&batch, *seq.last().unwrap(), W, &mut block);
                s.observe(&seq[seq.len() - 2..], &model_out);
            }
        });
        seq.truncate(base_len);
        if *has_index {
            assert!(
                n <= measure_steps as u64,
                "{label}: appending steady state allocated {n} times over \
                 {measure_steps} steps — amortised index growth must stay \
                 under one allocation per step (the seed did dozens per step)"
            );
        } else {
            assert!(
                n <= 8,
                "{label}: appending steady state allocated {n} times over \
                 {measure_steps} steps — table/cache strategies have no \
                 growing index and must stay allocation-free"
            );
        }
    }

    // --- phase 3: tree packing — overdraft proposal plus trie insertion
    // into the DraftTree arena must be EXACTLY zero allocations per step
    // once warm, with branching enabled (the mixed strategy's context and
    // ext-bigram rows share prefixes, so siblings really branch)
    let mut tree = DraftTree::new();
    {
        let (_, s, _) = &mut strategies[1]; // mixed: the engine's tree-mode strategy
        // warm: the tree's node/mask vectors grow to the overdraft shape
        for end in (PERIOD * 2..warm_len).step_by(2) {
            batch.reset(W);
            s.propose(&seq[..end], 2 * K, &mut batch);
            tree.reset(seq[end - 1], K, W);
            tree.insert_batch(&batch);
        }
        let mut sink = 0usize;
        let n = count_allocs(|| {
            for _ in 0..measure_steps {
                batch.reset(W);
                s.propose(&seq, 2 * K, &mut batch);
                tree.reset(*seq.last().unwrap(), K, W);
                tree.insert_batch(&batch);
                sink += tree.leaf_count() + tree.max_depth();
            }
        });
        assert!(sink > 0, "tree packing produced no nodes — workload broken");
        assert_eq!(
            n, 0,
            "tree packing: steady state must be allocation-free with branching \
             enabled ({n} allocations over {measure_steps} steps)"
        );
    }
}
