//! Serving-layer integration: scheduler + HTTP server over the synthetic
//! artifact tree — both the per-sequence worker mode and the
//! continuous-batching engine mode, plus the request-hardening paths.

use std::io::{BufReader, Read, Write};
use std::net::TcpStream;
use std::sync::Arc;

use ngrammys::config::{EngineConfig, Manifest, ServeConfig};
use ngrammys::scheduler::{GenRequest, Scheduler, StrategyName};
use ngrammys::server::{client, Server};
use ngrammys::tokenizer::BpeTokenizer;
use ngrammys::util::json::Json;

fn manifest() -> Manifest {
    ngrammys::testkit::manifest()
}

fn serve_cfg() -> ServeConfig {
    ServeConfig {
        addr: "127.0.0.1:0".into(),
        workers: 1,
        queue_cap: 8,
        batch: 0,
        default_engine: EngineConfig { k: 5, w: 4, q: 1, max_new_tokens: 12 },
        ..ServeConfig::default()
    }
}

#[test]
fn scheduler_round_trip() {
    let m = manifest();
    let sched = Scheduler::start(&m, "small", &serve_cfg()).unwrap();
    let tok = BpeTokenizer::load(&m.tokenizer_path).unwrap();
    let resp = sched
        .generate(GenRequest {
            prompt: tok.encode("Question: Tom has 3 apples."),
            engine: EngineConfig { k: 5, w: 4, q: 1, max_new_tokens: 10 },
            strategy: StrategyName::Mixed,
        })
        .unwrap();
    assert_eq!(resp.tokens.len(), 10);
    assert!(resp.tokens_per_call >= 1.0);
    assert_eq!(sched.metrics.requests_completed.load(std::sync::atomic::Ordering::Relaxed), 1);
    sched.shutdown();
}

#[test]
fn batched_scheduler_round_trip_matches_per_sequence() {
    // the SAME requests through both scheduler modes must produce the
    // SAME token streams — the engine swap is invisible to clients.
    let m = manifest();
    let tok = BpeTokenizer::load(&m.tokenizer_path).unwrap();
    let prompts = [
        "Question: Tom has 3 apples.",
        "def scale(x, y):",
        "User: What is the capital of France?",
        "Answer: Mia has 5 coins.",
    ];
    let req = |p: &str| GenRequest {
        prompt: tok.encode(p),
        engine: EngineConfig { k: 5, w: 4, q: 1, max_new_tokens: 12 },
        strategy: StrategyName::Mixed,
    };

    let seq_sched = Scheduler::start(&m, "small", &serve_cfg()).unwrap();
    let want: Vec<Vec<u32>> = prompts
        .iter()
        .map(|p| seq_sched.generate(req(p)).unwrap().tokens)
        .collect();
    seq_sched.shutdown();

    let mut cfg = serve_cfg();
    cfg.batch = 4;
    let bat_sched = Scheduler::start(&m, "small", &cfg).unwrap();
    // submit all four concurrently so they actually share packed calls
    let rxs: Vec<_> = prompts.iter().map(|p| bat_sched.submit(req(p)).unwrap()).collect();
    for (rx, want) in rxs.into_iter().zip(&want) {
        let got = rx.recv().unwrap().unwrap();
        assert_eq!(&got.tokens, want, "batched mode altered a token stream");
    }
    assert_eq!(
        bat_sched.metrics.requests_completed.load(std::sync::atomic::Ordering::Relaxed),
        prompts.len() as u64
    );
    bat_sched.shutdown();
}

#[test]
fn http_generate_metrics_and_errors() {
    let m = manifest();
    let cfg = serve_cfg();
    let sched = Arc::new(Scheduler::start(&m, "small", &cfg).unwrap());
    let tok = Arc::new(BpeTokenizer::load(&m.tokenizer_path).unwrap());
    let (addr, _h) = Server { scheduler: sched.clone(), tokenizer: tok, cfg }
        .spawn()
        .unwrap();
    let addr = addr.to_string();

    // healthz
    let (code, body) = client::get(&addr, "/healthz").unwrap();
    assert_eq!((code, body.trim()), (200, "ok"));

    // generate
    let (code, body) = client::post(
        &addr,
        "/generate",
        r#"{"prompt": "def scale(x):", "max_tokens": 8, "k": 5, "w": 4}"#,
    )
    .unwrap();
    assert_eq!(code, 200, "{body}");
    let j = Json::parse(&body).unwrap();
    assert_eq!(j.req("tokens").unwrap().as_usize(), Some(8));
    assert!(j.req("tokens_per_call").unwrap().as_f64().unwrap() >= 1.0);
    assert!(!j.req("text").unwrap().as_str().unwrap().is_empty());

    // strategy selection via API
    let (code, _) = client::post(
        &addr,
        "/generate",
        r#"{"prompt": "User: hi", "max_tokens": 4, "strategy": "jacobi"}"#,
    )
    .unwrap();
    assert_eq!(code, 200);

    // metrics reflect the requests
    let (code, metrics) = client::get(&addr, "/metrics").unwrap();
    assert_eq!(code, 200);
    assert!(metrics.contains("ngrammys_requests_completed 2"), "{metrics}");
    assert!(metrics.contains("ngrammys_tokens_per_call"));

    // error paths
    let (code, body) = client::post(&addr, "/generate", "{not json").unwrap();
    assert_eq!(code, 400, "{body}");
    let (code, _) = client::post(&addr, "/generate", r#"{"prompt": ""}"#).unwrap();
    assert_eq!(code, 400);
    let (code, _) = client::post(
        &addr, "/generate", r#"{"prompt": "x", "strategy": "bogus"}"#).unwrap();
    assert_eq!(code, 400);
    let (code, _) = client::get(&addr, "/nope").unwrap();
    assert_eq!(code, 404);
}

#[test]
fn stats_trace_and_route_hardening() {
    let m = manifest();
    let cfg = serve_cfg();
    let sched = Arc::new(Scheduler::start(&m, "small", &cfg).unwrap());
    let tok = Arc::new(BpeTokenizer::load(&m.tokenizer_path).unwrap());
    let (addr, _h) = Server { scheduler: sched, tokenizer: tok, cfg }.spawn().unwrap();
    let addr = addr.to_string();

    // serve a small workload so the latency digests have data
    for _ in 0..3 {
        let (code, body) = client::post(
            &addr,
            "/generate",
            r#"{"prompt": "def scale(x):", "max_tokens": 8, "k": 5, "w": 4}"#,
        )
        .unwrap();
        assert_eq!(code, 200, "{body}");
    }

    // /stats: non-zero p50/p99 TTFT and inter-token latency after a
    // served workload (the PR's acceptance bar)
    let (code, body) = client::get(&addr, "/stats").unwrap();
    assert_eq!(code, 200, "{body}");
    let j = Json::parse(&body).unwrap();
    assert_eq!(j.get("requests_completed").and_then(|v| v.as_f64()), Some(3.0));
    for digest in ["ttft_us", "inter_token_us"] {
        let d = j.get(digest).unwrap_or_else(|| panic!("missing {digest}: {body}"));
        assert_eq!(d.get("count").and_then(|v| v.as_f64()), Some(3.0), "{digest}: {body}");
        for q in ["p50_us", "p99_us"] {
            let v = d.get(q).and_then(|v| v.as_f64()).unwrap();
            assert!(v > 0.0, "{digest}.{q} must be non-zero after a workload: {body}");
        }
    }
    let verify = j.get("phases").and_then(|p| p.get("verify")).expect("verify phase digest");
    assert!(verify.get("count").and_then(|v| v.as_f64()).unwrap() > 0.0, "{body}");

    // /trace: parseable JSONL carrying both step and request events
    let (code, body) = client::get(&addr, "/trace?n=64").unwrap();
    assert_eq!(code, 200);
    let mut kinds = std::collections::BTreeSet::new();
    for line in body.lines() {
        let ev = Json::parse(line).unwrap_or_else(|e| panic!("bad JSONL line {line:?}: {e:#}"));
        kinds.insert(ev.get("type").and_then(|t| t.as_str()).unwrap().to_string());
    }
    assert!(kinds.contains("step") && kinds.contains("request"), "event kinds: {kinds:?}");

    // n=K caps the export
    let (_, body) = client::get(&addr, "/trace?n=1").unwrap();
    assert_eq!(body.lines().count(), 1, "{body}");

    // unknown path -> JSON 404 naming the path
    let (code, body) = client::get(&addr, "/no-such").unwrap();
    assert_eq!(code, 404);
    let err = Json::parse(&body).unwrap();
    assert!(err.get("error").and_then(|e| e.as_str()).unwrap().contains("/no-such"), "{body}");

    // method mismatch -> JSON 405, both directions
    let (code, body) = client::post(&addr, "/stats", "{}").unwrap();
    assert_eq!(code, 405, "{body}");
    assert!(Json::parse(&body).unwrap().get("error").is_some(), "{body}");
    let (code, body) = client::get(&addr, "/generate").unwrap();
    assert_eq!(code, 405, "{body}");
    assert!(Json::parse(&body).unwrap().get("error").is_some(), "{body}");
}

/// Send raw bytes and return (status, body) — for requests the well-formed
/// in-repo client cannot produce.
fn raw_request(addr: &str, payload: &str) -> (u16, String) {
    let mut stream = TcpStream::connect(addr).unwrap();
    stream.write_all(payload.as_bytes()).unwrap();
    let _ = stream.shutdown(std::net::Shutdown::Write);
    let mut buf = String::new();
    BufReader::new(stream).read_to_string(&mut buf).unwrap();
    let status: u16 = buf.split_whitespace().nth(1).unwrap_or("0").parse().unwrap_or(0);
    let body = buf.split_once("\r\n\r\n").map(|(_, b)| b.to_string()).unwrap_or_default();
    (status, body)
}

#[test]
fn hardened_request_parsing_returns_4xx_json() {
    let m = manifest();
    let cfg = serve_cfg();
    let sched = Arc::new(Scheduler::start(&m, "small", &cfg).unwrap());
    let tok = Arc::new(BpeTokenizer::load(&m.tokenizer_path).unwrap());
    let (addr, _h) = Server { scheduler: sched, tokenizer: tok, cfg }.spawn().unwrap();
    let addr = addr.to_string();

    // POST without Content-Length -> 411
    let (code, body) = raw_request(
        &addr,
        "POST /generate HTTP/1.1\r\nHost: x\r\n\r\n{\"prompt\": \"hi\"}",
    );
    assert_eq!(code, 411, "{body}");
    assert!(Json::parse(&body).unwrap().get("error").is_some(), "{body}");

    // absurd Content-Length -> 413, without attempting the allocation
    let (code, body) = raw_request(
        &addr,
        "POST /generate HTTP/1.1\r\nContent-Length: 999999999999\r\n\r\n",
    );
    assert_eq!(code, 413, "{body}");
    assert!(Json::parse(&body).unwrap().get("error").is_some());

    // non-numeric Content-Length -> 400
    let (code, _) = raw_request(
        &addr,
        "POST /generate HTTP/1.1\r\nContent-Length: banana\r\n\r\n",
    );
    assert_eq!(code, 400);

    // body shorter than the declared Content-Length -> 400, not a hang
    let (code, _) = raw_request(
        &addr,
        "POST /generate HTTP/1.1\r\nContent-Length: 50\r\n\r\n{\"a\":1}",
    );
    assert_eq!(code, 400);

    // garbage request line -> 400
    let (code, _) = raw_request(&addr, "\r\n\r\n");
    assert_eq!(code, 400);

    // the server survives all of the above and still serves
    let (code, body) = client::post(
        &addr,
        "/generate",
        r#"{"prompt": "User: hi", "max_tokens": 4}"#,
    )
    .unwrap();
    assert_eq!(code, 200, "{body}");
}

#[test]
fn backpressure_rejects_when_queue_full() {
    let m = manifest();
    let mut cfg = serve_cfg();
    cfg.queue_cap = 1;
    let sched = Scheduler::start(&m, "small", &cfg).unwrap();
    let tok = BpeTokenizer::load(&m.tokenizer_path).unwrap();
    let prompt = tok.encode("Question: Tom has 3 apples and 4 pens and 5 cards.");
    let req = || GenRequest {
        prompt: prompt.clone(),
        engine: EngineConfig { k: 10, w: 10, q: 1, max_new_tokens: 64 },
        strategy: StrategyName::Mixed,
    };
    // flood: exactly one can queue behind the in-flight one; the rest must
    // be rejected fast (not block)
    let mut rxs = Vec::new();
    let mut rejected = 0;
    for _ in 0..12 {
        match sched.submit(req()) {
            Ok(rx) => rxs.push(rx),
            Err(_) => rejected += 1,
        }
    }
    assert!(rejected >= 8, "only {rejected} rejected");
    for rx in rxs {
        let r = rx.recv().unwrap().unwrap();
        assert_eq!(r.tokens.len(), 64);
    }
    assert_eq!(
        sched.metrics.requests_rejected.load(std::sync::atomic::Ordering::Relaxed),
        rejected
    );
    // overflow is never a silent drop: the count is exported at /metrics
    // under the documented field name
    let rendered = sched.metrics.render();
    assert!(
        rendered.contains(&format!("ngrammys_requests_rejected {rejected}\n")),
        "rejections missing from /metrics: {rendered}"
    );
    sched.shutdown();
}
