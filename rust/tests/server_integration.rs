//! Serving-layer integration: scheduler + HTTP server over the synthetic
//! artifact tree — both the per-sequence worker mode and the
//! continuous-batching engine mode, plus the request-hardening paths.

use std::io::{BufReader, Read, Write};
use std::net::TcpStream;
use std::sync::Arc;
use std::time::{Duration, Instant};

use ngrammys::config::{EngineConfig, FrontEnd, Manifest, ServeConfig};
use ngrammys::scheduler::{GenRequest, Scheduler, StrategyName};
use ngrammys::server::{client, Server};
use ngrammys::tokenizer::BpeTokenizer;
use ngrammys::util::json::Json;

fn manifest() -> Manifest {
    ngrammys::testkit::manifest()
}

fn serve_cfg() -> ServeConfig {
    ServeConfig {
        addr: "127.0.0.1:0".into(),
        workers: 1,
        queue_cap: 8,
        batch: 0,
        default_engine: EngineConfig { k: 5, w: 4, q: 1, max_new_tokens: 12 },
        ..ServeConfig::default()
    }
}

#[test]
fn scheduler_round_trip() {
    let m = manifest();
    let sched = Scheduler::start(&m, "small", &serve_cfg()).unwrap();
    let tok = BpeTokenizer::load(&m.tokenizer_path).unwrap();
    let resp = sched
        .generate(GenRequest {
            prompt: tok.encode("Question: Tom has 3 apples."),
            engine: EngineConfig { k: 5, w: 4, q: 1, max_new_tokens: 10 },
            strategy: StrategyName::Mixed,
        })
        .unwrap();
    assert_eq!(resp.tokens.len(), 10);
    assert!(resp.tokens_per_call >= 1.0);
    assert_eq!(sched.metrics.requests_completed.load(std::sync::atomic::Ordering::Relaxed), 1);
    sched.shutdown();
}

#[test]
fn batched_scheduler_round_trip_matches_per_sequence() {
    // the SAME requests through both scheduler modes must produce the
    // SAME token streams — the engine swap is invisible to clients.
    let m = manifest();
    let tok = BpeTokenizer::load(&m.tokenizer_path).unwrap();
    let prompts = [
        "Question: Tom has 3 apples.",
        "def scale(x, y):",
        "User: What is the capital of France?",
        "Answer: Mia has 5 coins.",
    ];
    let req = |p: &str| GenRequest {
        prompt: tok.encode(p),
        engine: EngineConfig { k: 5, w: 4, q: 1, max_new_tokens: 12 },
        strategy: StrategyName::Mixed,
    };

    let seq_sched = Scheduler::start(&m, "small", &serve_cfg()).unwrap();
    let want: Vec<Vec<u32>> = prompts
        .iter()
        .map(|p| seq_sched.generate(req(p)).unwrap().tokens)
        .collect();
    seq_sched.shutdown();

    let mut cfg = serve_cfg();
    cfg.batch = 4;
    let bat_sched = Scheduler::start(&m, "small", &cfg).unwrap();
    // submit all four concurrently so they actually share packed calls
    let rxs: Vec<_> = prompts.iter().map(|p| bat_sched.submit(req(p)).unwrap()).collect();
    for (rx, want) in rxs.into_iter().zip(&want) {
        let got = rx.recv().unwrap().unwrap();
        assert_eq!(&got.tokens, want, "batched mode altered a token stream");
    }
    assert_eq!(
        bat_sched.metrics.requests_completed.load(std::sync::atomic::Ordering::Relaxed),
        prompts.len() as u64
    );
    bat_sched.shutdown();
}

#[test]
fn http_generate_metrics_and_errors() {
    let m = manifest();
    let cfg = serve_cfg();
    let sched = Arc::new(Scheduler::start(&m, "small", &cfg).unwrap());
    let tok = Arc::new(BpeTokenizer::load(&m.tokenizer_path).unwrap());
    let (addr, _h) = Server { scheduler: sched.clone(), tokenizer: tok, cfg }
        .spawn()
        .unwrap();
    let addr = addr.to_string();

    // healthz
    let (code, body) = client::get(&addr, "/healthz").unwrap();
    assert_eq!((code, body.trim()), (200, "ok"));

    // generate
    let (code, body) = client::post(
        &addr,
        "/generate",
        r#"{"prompt": "def scale(x):", "max_tokens": 8, "k": 5, "w": 4}"#,
    )
    .unwrap();
    assert_eq!(code, 200, "{body}");
    let j = Json::parse(&body).unwrap();
    assert_eq!(j.req("tokens").unwrap().as_usize(), Some(8));
    assert!(j.req("tokens_per_call").unwrap().as_f64().unwrap() >= 1.0);
    assert!(!j.req("text").unwrap().as_str().unwrap().is_empty());

    // strategy selection via API
    let (code, _) = client::post(
        &addr,
        "/generate",
        r#"{"prompt": "User: hi", "max_tokens": 4, "strategy": "jacobi"}"#,
    )
    .unwrap();
    assert_eq!(code, 200);

    // metrics reflect the requests
    let (code, metrics) = client::get(&addr, "/metrics").unwrap();
    assert_eq!(code, 200);
    assert!(metrics.contains("ngrammys_requests_completed 2"), "{metrics}");
    assert!(metrics.contains("ngrammys_tokens_per_call"));

    // error paths
    let (code, body) = client::post(&addr, "/generate", "{not json").unwrap();
    assert_eq!(code, 400, "{body}");
    let (code, _) = client::post(&addr, "/generate", r#"{"prompt": ""}"#).unwrap();
    assert_eq!(code, 400);
    let (code, _) = client::post(
        &addr, "/generate", r#"{"prompt": "x", "strategy": "bogus"}"#).unwrap();
    assert_eq!(code, 400);
    let (code, _) = client::get(&addr, "/nope").unwrap();
    assert_eq!(code, 404);
}

#[test]
fn stats_trace_and_route_hardening() {
    let m = manifest();
    let cfg = serve_cfg();
    let sched = Arc::new(Scheduler::start(&m, "small", &cfg).unwrap());
    let tok = Arc::new(BpeTokenizer::load(&m.tokenizer_path).unwrap());
    let (addr, _h) = Server { scheduler: sched, tokenizer: tok, cfg }.spawn().unwrap();
    let addr = addr.to_string();

    // serve a small workload so the latency digests have data
    for _ in 0..3 {
        let (code, body) = client::post(
            &addr,
            "/generate",
            r#"{"prompt": "def scale(x):", "max_tokens": 8, "k": 5, "w": 4}"#,
        )
        .unwrap();
        assert_eq!(code, 200, "{body}");
    }

    // /stats: non-zero p50/p99 TTFT and inter-token latency after a
    // served workload (the PR's acceptance bar)
    let (code, body) = client::get(&addr, "/stats").unwrap();
    assert_eq!(code, 200, "{body}");
    let j = Json::parse(&body).unwrap();
    assert_eq!(j.get("requests_completed").and_then(|v| v.as_f64()), Some(3.0));
    for digest in ["ttft_us", "inter_token_us"] {
        let d = j.get(digest).unwrap_or_else(|| panic!("missing {digest}: {body}"));
        assert_eq!(d.get("count").and_then(|v| v.as_f64()), Some(3.0), "{digest}: {body}");
        for q in ["p50_us", "p99_us"] {
            let v = d.get(q).and_then(|v| v.as_f64()).unwrap();
            assert!(v > 0.0, "{digest}.{q} must be non-zero after a workload: {body}");
        }
    }
    let verify = j.get("phases").and_then(|p| p.get("verify")).expect("verify phase digest");
    assert!(verify.get("count").and_then(|v| v.as_f64()).unwrap() > 0.0, "{body}");

    // /trace: parseable JSONL carrying both step and request events
    let (code, body) = client::get(&addr, "/trace?n=64").unwrap();
    assert_eq!(code, 200);
    let mut kinds = std::collections::BTreeSet::new();
    for line in body.lines() {
        let ev = Json::parse(line).unwrap_or_else(|e| panic!("bad JSONL line {line:?}: {e:#}"));
        kinds.insert(ev.get("type").and_then(|t| t.as_str()).unwrap().to_string());
    }
    assert!(kinds.contains("step") && kinds.contains("request"), "event kinds: {kinds:?}");

    // n=K caps the export
    let (_, body) = client::get(&addr, "/trace?n=1").unwrap();
    assert_eq!(body.lines().count(), 1, "{body}");

    // unknown path -> JSON 404 naming the path
    let (code, body) = client::get(&addr, "/no-such").unwrap();
    assert_eq!(code, 404);
    let err = Json::parse(&body).unwrap();
    assert!(err.get("error").and_then(|e| e.as_str()).unwrap().contains("/no-such"), "{body}");

    // method mismatch -> JSON 405, both directions
    let (code, body) = client::post(&addr, "/stats", "{}").unwrap();
    assert_eq!(code, 405, "{body}");
    assert!(Json::parse(&body).unwrap().get("error").is_some(), "{body}");
    let (code, body) = client::get(&addr, "/generate").unwrap();
    assert_eq!(code, 405, "{body}");
    assert!(Json::parse(&body).unwrap().get("error").is_some(), "{body}");
}

/// Send raw bytes and return (status, body) — for requests the well-formed
/// in-repo client cannot produce.
fn raw_request(addr: &str, payload: &str) -> (u16, String) {
    let mut stream = TcpStream::connect(addr).unwrap();
    stream.write_all(payload.as_bytes()).unwrap();
    let _ = stream.shutdown(std::net::Shutdown::Write);
    let mut buf = String::new();
    BufReader::new(stream).read_to_string(&mut buf).unwrap();
    let status: u16 = buf.split_whitespace().nth(1).unwrap_or("0").parse().unwrap_or(0);
    let body = buf.split_once("\r\n\r\n").map(|(_, b)| b.to_string()).unwrap_or_default();
    (status, body)
}

#[test]
fn hardened_request_parsing_returns_4xx_json() {
    let m = manifest();
    let cfg = serve_cfg();
    let sched = Arc::new(Scheduler::start(&m, "small", &cfg).unwrap());
    let tok = Arc::new(BpeTokenizer::load(&m.tokenizer_path).unwrap());
    let (addr, _h) = Server { scheduler: sched, tokenizer: tok, cfg }.spawn().unwrap();
    let addr = addr.to_string();

    // POST without Content-Length -> 411
    let (code, body) = raw_request(
        &addr,
        "POST /generate HTTP/1.1\r\nHost: x\r\n\r\n{\"prompt\": \"hi\"}",
    );
    assert_eq!(code, 411, "{body}");
    assert!(Json::parse(&body).unwrap().get("error").is_some(), "{body}");

    // absurd Content-Length -> 413, without attempting the allocation
    let (code, body) = raw_request(
        &addr,
        "POST /generate HTTP/1.1\r\nContent-Length: 999999999999\r\n\r\n",
    );
    assert_eq!(code, 413, "{body}");
    assert!(Json::parse(&body).unwrap().get("error").is_some());

    // non-numeric Content-Length -> 400
    let (code, _) = raw_request(
        &addr,
        "POST /generate HTTP/1.1\r\nContent-Length: banana\r\n\r\n",
    );
    assert_eq!(code, 400);

    // body shorter than the declared Content-Length -> 400, not a hang
    let (code, _) = raw_request(
        &addr,
        "POST /generate HTTP/1.1\r\nContent-Length: 50\r\n\r\n{\"a\":1}",
    );
    assert_eq!(code, 400);

    // garbage request line -> 400
    let (code, _) = raw_request(&addr, "\r\n\r\n");
    assert_eq!(code, 400);

    // the server survives all of the above and still serves
    let (code, body) = client::post(
        &addr,
        "/generate",
        r#"{"prompt": "User: hi", "max_tokens": 4}"#,
    )
    .unwrap();
    assert_eq!(code, 200, "{body}");
}

/// Like [`raw_request`] but returning the FULL response — status line,
/// headers and body — for byte-level front-end comparisons.
fn raw_response(addr: &str, payload: &str) -> String {
    let mut stream = TcpStream::connect(addr).unwrap();
    stream.write_all(payload.as_bytes()).unwrap();
    let _ = stream.shutdown(std::net::Shutdown::Write);
    let mut buf = String::new();
    BufReader::new(stream).read_to_string(&mut buf).unwrap();
    buf
}

/// Read one `<name> N` counter line out of a `/metrics` render.
fn counter(metrics: &str, name: &str) -> u64 {
    metrics
        .lines()
        .find_map(|l| l.strip_prefix(&format!("{name} ")))
        .and_then(|v| v.trim().parse().ok())
        .unwrap_or(0)
}

/// Poll `/metrics` until `pred` passes or a 10s deadline expires;
/// returns the render that satisfied it.
fn wait_for_metrics(addr: &str, what: &str, pred: impl Fn(&str) -> bool) -> String {
    let deadline = Instant::now() + Duration::from_secs(10);
    loop {
        let (_, m) = client::get(addr, "/metrics").unwrap();
        if pred(&m) {
            return m;
        }
        assert!(Instant::now() < deadline, "timed out waiting for {what}; metrics:\n{m}");
        std::thread::sleep(Duration::from_millis(10));
    }
}

#[test]
fn reactor_and_threaded_front_ends_are_byte_identical() {
    let m = manifest();
    let tok = Arc::new(BpeTokenizer::load(&m.tokenizer_path).unwrap());
    let run_against = |fe: FrontEnd| -> (Vec<String>, Vec<String>) {
        let mut cfg = serve_cfg();
        cfg.front_end = fe;
        let sched = Arc::new(Scheduler::start(&m, "small", &cfg).unwrap());
        let handle =
            Server { scheduler: sched, tokenizer: tok.clone(), cfg }.spawn_handle().unwrap();
        let addr = handle.addr.to_string();
        // deterministic /generate fields (latency_ms varies per run)
        let mut texts = Vec::new();
        for p in ["Question: Tom has 3 apples.", "def scale(x, y):"] {
            let (code, body) = client::post(
                &addr,
                "/generate",
                &format!(r#"{{"prompt": "{p}", "max_tokens": 8}}"#),
            )
            .unwrap();
            assert_eq!(code, 200, "{body}");
            let j = Json::parse(&body).unwrap();
            texts.push(j.req("text").unwrap().as_str().unwrap().to_string());
            texts.push(j.req("tokens").unwrap().to_string());
        }
        // the raw hardening corpus: the FULL response — headers included —
        // must come back byte-identical from both front-ends
        let corpus = [
            "POST /generate HTTP/1.1\r\nHost: x\r\n\r\n{\"prompt\": \"hi\"}",
            "POST /generate HTTP/1.1\r\nContent-Length: 999999999999\r\n\r\n",
            "POST /generate HTTP/1.1\r\nContent-Length: banana\r\n\r\n",
            "POST /generate HTTP/1.1\r\nContent-Length: 50\r\n\r\n{\"a\":1}",
            "\r\n\r\n",
            "GET /nope HTTP/1.1\r\n\r\n",
            "PUT /stats HTTP/1.1\r\nContent-Length: 0\r\n\r\n",
            "GET /healthz HTTP/1.1\r\n\r\n",
        ];
        let raw: Vec<String> = corpus.iter().map(|p| raw_response(&addr, p)).collect();
        handle.shutdown();
        (texts, raw)
    };
    let (texts_r, raw_r) = run_against(FrontEnd::Reactor);
    let (texts_t, raw_t) = run_against(FrontEnd::Threaded);
    assert_eq!(texts_r, texts_t, "/generate output differs between front-ends");
    assert_eq!(raw_r, raw_t, "raw responses differ between front-ends");
}

#[test]
fn disconnect_mid_flight_cancels_and_is_visible_in_metrics() {
    let m = manifest();
    let cfg = serve_cfg(); // single worker, reactor front-end (default)
    let sched = Arc::new(Scheduler::start(&m, "small", &cfg).unwrap());
    let tok = Arc::new(BpeTokenizer::load(&m.tokenizer_path).unwrap());
    let (addr, _h) =
        Server { scheduler: sched, tokenizer: tok, cfg }.spawn().unwrap();
    let addr = addr.to_string();

    // occupy the single worker: four long generations serialize on it,
    // which holds the queue busy while the victim below is cancelled
    let blockers: Vec<_> = (0..4)
        .map(|i| {
            let a = addr.clone();
            std::thread::spawn(move || {
                client::post(
                    &a,
                    "/generate",
                    &format!(
                        r#"{{"prompt": "Question: Tom has {i} apples and 4 pens.", "max_tokens": 64}}"#
                    ),
                )
                .unwrap()
            })
        })
        .collect();
    std::thread::sleep(Duration::from_millis(30));

    // the victim: a valid request queued behind the blockers, whose
    // client then vanishes without half-close (a real disconnect)
    let body = r#"{"prompt": "def scale(x, y):", "max_tokens": 32}"#;
    let mut victim = TcpStream::connect(&addr).unwrap();
    victim
        .write_all(
            format!("POST /generate HTTP/1.1\r\nContent-Length: {}\r\n\r\n{body}", body.len())
                .as_bytes(),
        )
        .unwrap();
    std::thread::sleep(Duration::from_millis(30)); // reactor dispatches it
    drop(victim); // full close -> EOF on a Dispatched connection

    // the reactor counts the disconnect and cancels the in-flight token;
    // the worker then skips the dead request without decoding a step
    wait_for_metrics(&addr, "disconnect + cancellation", |m| {
        counter(m, "ngrammys_disconnects") >= 1 && counter(m, "ngrammys_requests_cancelled") >= 1
    });

    // co-resident requests are untouched by the cancellation
    for b in blockers {
        let (code, body) = b.join().unwrap();
        assert_eq!(code, 200, "blocker failed after a disconnect: {body}");
    }
    let m = wait_for_metrics(&addr, "blockers to complete", |m| {
        counter(m, "ngrammys_requests_completed") >= 4
    });
    assert!(counter(&m, "ngrammys_connections_total") >= 5, "{m}");
}

#[test]
fn slow_and_idle_connections_do_not_stall_other_streams() {
    let m = manifest();
    let cfg = serve_cfg(); // reactor front-end (default)
    let sched = Arc::new(Scheduler::start(&m, "small", &cfg).unwrap());
    let tok = Arc::new(BpeTokenizer::load(&m.tokenizer_path).unwrap());
    let (addr, _h) =
        Server { scheduler: sched, tokenizer: tok, cfg }.spawn().unwrap();
    let addr = addr.to_string();

    // park connections in every lazy state the event loop must tolerate:
    // connected-but-silent, half a request line, and a full request whose
    // client never reads the response
    let idle = TcpStream::connect(&addr).unwrap();
    let mut dribble = TcpStream::connect(&addr).unwrap();
    dribble.write_all(b"POST /gen").unwrap();
    let deaf_body = r#"{"prompt": "User: hi", "max_tokens": 4}"#;
    let mut deaf = TcpStream::connect(&addr).unwrap();
    deaf.write_all(
        format!("POST /generate HTTP/1.1\r\nContent-Length: {}\r\n\r\n{deaf_body}", deaf_body.len())
            .as_bytes(),
    )
    .unwrap();

    // co-resident streams complete promptly while all three sit there
    for _ in 0..3 {
        let (code, body) = client::post(
            &addr,
            "/generate",
            r#"{"prompt": "Question: Tom has 3 apples.", "max_tokens": 8}"#,
        )
        .unwrap();
        assert_eq!(code, 200, "a parked connection stalled a live stream: {body}");
    }
    let (code, _) = client::get(&addr, "/healthz").unwrap();
    assert_eq!(code, 200);
    drop((idle, dribble, deaf));
}

#[test]
fn graceful_shutdown_drains_in_flight_requests() {
    let m = manifest();
    let cfg = serve_cfg();
    let sched = Arc::new(Scheduler::start(&m, "small", &cfg).unwrap());
    let tok = Arc::new(BpeTokenizer::load(&m.tokenizer_path).unwrap());
    let handle =
        Server { scheduler: sched.clone(), tokenizer: tok, cfg }.spawn_handle().unwrap();
    let addr = handle.addr.to_string();

    let c_addr = addr.clone();
    let in_flight = std::thread::spawn(move || {
        client::post(
            &c_addr,
            "/generate",
            r#"{"prompt": "Question: Tom has 3 apples.", "max_tokens": 32}"#,
        )
        .unwrap()
    });
    std::thread::sleep(Duration::from_millis(30)); // request reaches the engine
    handle.shutdown(); // stop accepting, drain, join

    // the in-flight response was delivered, not severed
    let (code, body) = in_flight.join().unwrap();
    assert_eq!(code, 200, "in-flight request dropped during shutdown: {body}");
    // the listener is gone...
    assert!(TcpStream::connect(&addr).is_err(), "listener still accepting after shutdown");
    // ...and the server released its scheduler handle, proving the drain
    // actually completed (otherwise the Arc still has two owners)
    let sched = Arc::try_unwrap(sched)
        .unwrap_or_else(|_| panic!("server still holds the scheduler after shutdown"));
    sched.shutdown();
}

#[test]
fn backpressure_rejects_when_queue_full() {
    let m = manifest();
    let mut cfg = serve_cfg();
    cfg.queue_cap = 1;
    let sched = Scheduler::start(&m, "small", &cfg).unwrap();
    let tok = BpeTokenizer::load(&m.tokenizer_path).unwrap();
    let prompt = tok.encode("Question: Tom has 3 apples and 4 pens and 5 cards.");
    let req = || GenRequest {
        prompt: prompt.clone(),
        engine: EngineConfig { k: 10, w: 10, q: 1, max_new_tokens: 64 },
        strategy: StrategyName::Mixed,
    };
    // flood: exactly one can queue behind the in-flight one; the rest must
    // be rejected fast (not block)
    let mut rxs = Vec::new();
    let mut rejected = 0;
    for _ in 0..12 {
        match sched.submit(req()) {
            Ok(rx) => rxs.push(rx),
            Err(_) => rejected += 1,
        }
    }
    assert!(rejected >= 8, "only {rejected} rejected");
    for rx in rxs {
        let r = rx.recv().unwrap().unwrap();
        assert_eq!(r.tokens.len(), 64);
    }
    assert_eq!(
        sched.metrics.requests_rejected.load(std::sync::atomic::Ordering::Relaxed),
        rejected
    );
    // overflow is never a silent drop: the count is exported at /metrics
    // under the documented field name
    let rendered = sched.metrics.render();
    assert!(
        rendered.contains(&format!("ngrammys_requests_rejected {rejected}\n")),
        "rejections missing from /metrics: {rendered}"
    );
    sched.shutdown();
}
