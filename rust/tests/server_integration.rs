//! Serving-layer integration: scheduler + HTTP server over real artifacts.

use std::sync::Arc;

use ngrammys::config::{default_artifacts_dir, EngineConfig, Manifest, ServeConfig};
use ngrammys::scheduler::{GenRequest, Scheduler, StrategyName};
use ngrammys::server::{client, Server};
use ngrammys::tokenizer::BpeTokenizer;
use ngrammys::util::json::Json;

fn manifest() -> Manifest {
    Manifest::load(&default_artifacts_dir()).expect("run `make artifacts` first")
}

fn serve_cfg() -> ServeConfig {
    ServeConfig {
        addr: "127.0.0.1:0".into(),
        workers: 1,
        queue_cap: 8,
        default_engine: EngineConfig { k: 5, w: 4, q: 1, max_new_tokens: 12 },
    }
}

#[test]
fn scheduler_round_trip() {
    let m = manifest();
    let sched = Scheduler::start(&m, "small", &serve_cfg()).unwrap();
    let tok = BpeTokenizer::load(&m.tokenizer_path).unwrap();
    let resp = sched
        .generate(GenRequest {
            prompt: tok.encode("Question: Tom has 3 apples."),
            engine: EngineConfig { k: 5, w: 4, q: 1, max_new_tokens: 10 },
            strategy: StrategyName::Mixed,
        })
        .unwrap();
    assert_eq!(resp.tokens.len(), 10);
    assert!(resp.tokens_per_call >= 1.0);
    assert_eq!(sched.metrics.requests_completed.load(std::sync::atomic::Ordering::Relaxed), 1);
    sched.shutdown();
}

#[test]
fn http_generate_metrics_and_errors() {
    let m = manifest();
    let cfg = serve_cfg();
    let sched = Arc::new(Scheduler::start(&m, "small", &cfg).unwrap());
    let tok = Arc::new(BpeTokenizer::load(&m.tokenizer_path).unwrap());
    let (addr, _h) = Server { scheduler: sched.clone(), tokenizer: tok, cfg }
        .spawn()
        .unwrap();
    let addr = addr.to_string();

    // healthz
    let (code, body) = client::get(&addr, "/healthz").unwrap();
    assert_eq!((code, body.trim()), (200, "ok"));

    // generate
    let (code, body) = client::post(
        &addr,
        "/generate",
        r#"{"prompt": "def scale(x):", "max_tokens": 8, "k": 5, "w": 4}"#,
    )
    .unwrap();
    assert_eq!(code, 200, "{body}");
    let j = Json::parse(&body).unwrap();
    assert_eq!(j.req("tokens").unwrap().as_usize(), Some(8));
    assert!(j.req("tokens_per_call").unwrap().as_f64().unwrap() >= 1.0);
    assert!(!j.req("text").unwrap().as_str().unwrap().is_empty());

    // strategy selection via API
    let (code, _) = client::post(
        &addr,
        "/generate",
        r#"{"prompt": "User: hi", "max_tokens": 4, "strategy": "jacobi"}"#,
    )
    .unwrap();
    assert_eq!(code, 200);

    // metrics reflect the requests
    let (code, metrics) = client::get(&addr, "/metrics").unwrap();
    assert_eq!(code, 200);
    assert!(metrics.contains("ngrammys_requests_completed 2"), "{metrics}");
    assert!(metrics.contains("ngrammys_tokens_per_call"));

    // error paths
    let (code, body) = client::post(&addr, "/generate", "{not json").unwrap();
    assert_eq!(code, 400, "{body}");
    let (code, _) = client::post(&addr, "/generate", r#"{"prompt": ""}"#).unwrap();
    assert_eq!(code, 400);
    let (code, _) = client::post(
        &addr, "/generate", r#"{"prompt": "x", "strategy": "bogus"}"#).unwrap();
    assert_eq!(code, 400);
    let (code, _) = client::get(&addr, "/nope").unwrap();
    assert_eq!(code, 404);
}

#[test]
fn backpressure_rejects_when_queue_full() {
    let m = manifest();
    let mut cfg = serve_cfg();
    cfg.queue_cap = 1;
    let sched = Scheduler::start(&m, "small", &cfg).unwrap();
    let tok = BpeTokenizer::load(&m.tokenizer_path).unwrap();
    let prompt = tok.encode("Question: Tom has 3 apples and 4 pens and 5 cards.");
    let req = || GenRequest {
        prompt: prompt.clone(),
        engine: EngineConfig { k: 10, w: 10, q: 1, max_new_tokens: 64 },
        strategy: StrategyName::Mixed,
    };
    // flood: exactly one can queue behind the in-flight one; the rest must
    // be rejected fast (not block)
    let mut rxs = Vec::new();
    let mut rejected = 0;
    for _ in 0..12 {
        match sched.submit(req()) {
            Ok(rx) => rxs.push(rx),
            Err(_) => rejected += 1,
        }
    }
    assert!(rejected >= 8, "only {rejected} rejected");
    for rx in rxs {
        let r = rx.recv().unwrap().unwrap();
        assert_eq!(r.tokens.len(), 64);
    }
    assert_eq!(
        sched.metrics.requests_rejected.load(std::sync::atomic::Ordering::Relaxed),
        rejected
    );
    sched.shutdown();
}
