//! Property-based tests on coordinator invariants (in-repo prop harness;
//! proptest is unavailable offline). Pure components get hundreds of random
//! cases; the real-runtime property runs a smaller case count.

use ngrammys::draft::tables::Table;
use ngrammys::draft::{ContextNgram, DraftBatch, DraftStrategy, MixedStrategy, NgramTables};
use ngrammys::engine::acceptance::{judge, row_accept_len};
use ngrammys::kvcache::SharedKvCache;
use ngrammys::util::prop;
use ngrammys::util::rng::Rng;
use std::sync::Arc;

fn random_tables(rng: &mut Rng, vocab: usize, topk: usize, depth: usize) -> Arc<NgramTables> {
    let mut mk = |n: usize| -> Vec<u32> {
        (0..n).map(|_| rng.below(vocab) as u32).collect()
    };
    let bigram = mk(vocab * topk);
    let unigram = mk(topk);
    let ext = mk(vocab * topk * depth);
    Arc::new(NgramTables {
        bigram: Table::from_data(vocab, topk, 1, bigram),
        unigram: Table::from_data(1, topk, 1, unigram),
        ext_bigram: Table::from_data(vocab, topk, depth, ext),
    })
}

#[test]
fn prop_context_ngram_candidates_are_real_continuations() {
    // every candidate must literally appear after an occurrence of the query
    prop::check(400, |rng| {
        let vocab = rng.range(3, 12);
        let len = rng.range(2, 120);
        let q = rng.range(1, 3);
        let w = rng.range(1, 6);
        let seq = prop::vec_u32(rng, len, 0..vocab as u32);
        let mut ctx = ContextNgram::new(q);
        for (cand, count) in ctx.candidates(&seq, w) {
            if seq.len() < q + 1 {
                return false;
            }
            let query = &seq[seq.len() - q..];
            let mut found = 0u32;
            for i in 0..seq.len() - q {
                if &seq[i..i + q] == query && seq[i + q..].starts_with(&cand) {
                    found += 1;
                }
            }
            if found < count {
                return false; // counted more matches than exist
            }
            if cand.is_empty() || cand.len() > w {
                return false;
            }
        }
        true
    });
}

#[test]
fn prop_mixed_fills_k_distinct_rows_when_possible() {
    prop::check(300, |rng| {
        let vocab = rng.range(16, 64);
        let topk = rng.range(8, 16);
        let tables = random_tables(rng, vocab, topk, 8);
        let k = rng.range(1, topk.min(8));
        let w = rng.range(1, 8);
        let slen = rng.range(1, 60);
        let seq = prop::vec_u32(rng, slen, 0..vocab as u32);
        let mut m = MixedStrategy::paper(tables, 1);
        let mut b = DraftBatch::new(w);
        m.propose(&seq, k, &mut b);
        if b.k() > k {
            return false;
        }
        // all rows distinct
        for i in 0..b.k() {
            for j in 0..i {
                if b.row_tokens(i) == b.row_tokens(j) {
                    return false;
                }
            }
        }
        // rows never exceed w
        b.rows().iter().all(|r| r.len() <= w)
    });
}

#[test]
fn prop_acceptance_never_exceeds_draft_len_and_always_emits() {
    prop::check(500, |rng| {
        let w = rng.range(0, 8);
        let k = rng.range(1, 6);
        let w1 = w + 1;
        let mut b = DraftBatch::new(w);
        for _ in 0..k {
            let rl = rng.range(0, w);
            b.push(prop::vec_u32(rng, rl, 0..16), ngrammys::draft::StrategyKind::Jacobi, 0);
        }
        let out = prop::vec_u32(rng, k * w1, 0..16);
        let a = judge(&b, &out, w1);
        a.row < k
            && a.accepted <= w
            && a.emitted.len() == a.accepted + 1
            && a.accepted <= b.row_tokens(a.row).len()
    });
}

#[test]
fn prop_row_accept_len_is_common_prefix() {
    prop::check(500, |rng| {
        let n = rng.range(0, 10);
        let d = prop::vec_u32(rng, n, 0..4);
        let olen = rng.range(n, n + 2);
        let o = prop::vec_u32(rng, olen, 0..4);
        let a = row_accept_len(&d, &o);
        // definition check
        let ok_prefix = (0..a).all(|i| d[i] == o[i]);
        let maximal = a == d.len() || a >= o.len() || d[a] != o[a];
        ok_prefix && maximal
    });
}

#[test]
fn prop_kv_commit_roundtrip_preserves_layout() {
    // committing tails and reading them back must land at the right
    // (layer, position) offsets for arbitrary shapes
    prop::check(200, |rng| {
        let layers = rng.range(1, 4);
        let heads = rng.range(1, 4);
        let hd = [2usize, 4, 8][rng.below(3)];
        let max_len = rng.range(8, 32);
        let mut c = SharedKvCache::new(layers, max_len, heads, hd);
        let k_rows = rng.range(1, 4);
        let w1 = rng.range(1, 5.min(max_len));
        c.len = rng.range(0, max_len - w1);
        let start_len = c.len;
        let ps = c.pos_stride();
        let n = layers * k_rows * w1 * ps;
        // encode source coordinates in the values
        let k_tail: Vec<f32> = (0..n).map(|i| i as f32).collect();
        let v_tail: Vec<f32> = (0..n).map(|i| -(i as f32)).collect();
        let row = rng.below(k_rows);
        let count = rng.range(1, w1);
        if c.commit_tail(&k_tail, &v_tail, k_rows, w1, row, count).is_err() {
            return false;
        }
        if c.len != start_len + count {
            return false;
        }
        for layer in 0..layers {
            for pos in 0..count {
                let src = ((layer * k_rows + row) * w1 + pos) * ps;
                let dst = layer * c.layer_stride() + (start_len + pos) * ps;
                for e in 0..ps {
                    if c.k_data[dst + e] != k_tail[src + e]
                        || c.v_data[dst + e] != v_tail[src + e]
                    {
                        return false;
                    }
                }
            }
        }
        true
    });
}

// Block conservation for the paged KV cache lives in
// rust/tests/paged_kv.rs now: the live PagedKvPool audits refcount /
// reserve / budget balance after every operation of random
// trajectories, which subsumes the old free-standing allocator test.

#[test]
fn prop_json_roundtrip() {
    use ngrammys::util::json::Json;
    fn random_json(rng: &mut Rng, depth: usize) -> Json {
        match if depth == 0 { rng.below(4) } else { rng.below(6) } {
            0 => Json::Null,
            1 => Json::Bool(rng.below(2) == 0),
            2 => Json::Num((rng.below(100000) as f64) - 50000.0 + 0.5),
            3 => {
                let n = rng.range(0, 12);
                Json::Str((0..n).map(|_| {
                    let c = [b'a', b'"', b'\\', b'\n', 0xc3].map(|b| b as char);
                    // keep valid utf-8: replace the raw byte with é
                    let ch = c[rng.below(4)];
                    ch
                }).collect())
            }
            4 => Json::Arr((0..rng.range(0, 4)).map(|_| random_json(rng, depth - 1)).collect()),
            _ => Json::Obj((0..rng.range(0, 4)).map(|i| {
                (format!("k{i}"), random_json(rng, depth - 1))
            }).collect()),
        }
    }
    prop::check(300, |rng| {
        let j = random_json(rng, 3);
        let compact = Json::parse(&j.to_string());
        let pretty = Json::parse(&j.to_string_pretty());
        compact.map(|c| c == j).unwrap_or(false)
            && pretty.map(|p| p == j).unwrap_or(false)
    });
}

/// The headline invariant against the full runtime: for random prompt
/// slices and random (k, w) shapes, speculative decoding emits the greedy
/// stream.
#[test]
fn prop_real_model_speculation_is_lossless() {
    use ngrammys::bench::BenchCtx;
    use ngrammys::config::EngineConfig;
    use ngrammys::engine::{greedy_config, NoDraft, SpecDecoder};
    use ngrammys::scheduler::{make_strategy, StrategyName};

    let manifest = ngrammys::testkit::manifest();
    let ctx = BenchCtx::load(manifest, "small").unwrap();
    let corpus = std::fs::read_to_string(
        &ctx.manifest.data["code"].1).unwrap();
    let shapes: Vec<(usize, usize)> = ctx.runtime.artifacts().step_shapes();

    prop::check(8, |rng| {
        let start = rng.below(corpus.len().saturating_sub(400));
        // align to char boundary
        let mut s = start;
        while !corpus.is_char_boundary(s) {
            s += 1;
        }
        let text = &corpus[s..(s + 200).min(corpus.len())];
        let mut toks = ctx.tokenizer.encode(text);
        toks.truncate(48);
        if toks.len() < 4 {
            return true;
        }
        let (k, w) = shapes[rng.below(shapes.len())];
        let max_new = rng.range(4, 24);

        let mut greedy = SpecDecoder::new(
            &ctx.runtime, Box::new(NoDraft), greedy_config(max_new));
        let want = greedy.generate(&toks).unwrap().tokens;

        let strat = [StrategyName::Mixed, StrategyName::Context, StrategyName::Jacobi]
            [rng.below(3)];
        let s = make_strategy(strat, &ctx.tables, 1);
        let mut dec = SpecDecoder::new(
            &ctx.runtime, s, EngineConfig { k, w, q: 1, max_new_tokens: max_new });
        dec.generate(&toks).unwrap().tokens == want
    });
}
