//! Adaptive-mode invariants: the controller's output stream must be
//! byte-identical to greedy/static decoding across randomized (k, w) and
//! strategy trajectories — in both `SpecDecoder` and `BatchedEngine`
//! (concurrency 1/4/8) — and the batched engine must never pack more than
//! the configured row budget in any step.

use std::collections::HashMap;

use ngrammys::adaptive::{self, AdaptiveConfig, SeqController};
use ngrammys::bench::BenchCtx;
use ngrammys::config::{EngineConfig, SessionCacheConfig};
use ngrammys::draft::{DraftBatch, DraftStrategy};
use ngrammys::engine::{greedy_config, BatchedEngine, NoDraft, SpecDecoder};
use ngrammys::scheduler::{make_strategy, StrategyName};
use ngrammys::tokenizer::TokenId;
use ngrammys::util::rng::Rng;

fn ctx(model: &str) -> BenchCtx {
    BenchCtx::load(ngrammys::testkit::manifest(), model).unwrap()
}

fn prompts(c: &BenchCtx) -> Vec<Vec<u32>> {
    [
        "Question: Tom has 4 apples. Tom buys 2 more.",
        "def scale(x, y):\n    result",
        "User: What is the capital of France?",
        "Answer: Mia has 5 coins.",
        "def blend(value, count):",
        "User: Tell me about ancient rivers.",
        "Question: Sam has 7 cards.",
        "Assistant: That is a good question.",
    ]
    .iter()
    .map(|p| c.tokenizer.encode(p))
    .collect()
}

fn greedy_stream(c: &BenchCtx, prompt: &[u32], max_new: usize) -> Vec<u32> {
    let mut dec = SpecDecoder::new(&c.runtime, Box::new(NoDraft), greedy_config(max_new));
    dec.generate(prompt).unwrap().tokens
}

fn controller(c: &BenchCtx, cfg: AdaptiveConfig) -> SeqController {
    let mut ctl = adaptive::controller_for(
        &c.tables,
        1,
        &SessionCacheConfig::default(),
        &c.runtime.artifacts().dims.analog,
    );
    ctl.cfg = cfg;
    ctl
}

fn random_cfg(rng: &mut Rng) -> AdaptiveConfig {
    AdaptiveConfig {
        alpha: 0.05 + rng.f64() * 0.9,
        explore: rng.f64(),
        warmup: rng.below(3),
        depth_optimism: 1.0 + rng.f64() * 2.0,
    }
}

/// A worst-case "trajectory": every step drafts with a randomly chosen
/// strategy, so the stream of (strategy, proposal) pairs is arbitrary.
/// Losslessness must hold anyway — acceptance never trusts a draft.
struct ShuffledArms {
    arms: Vec<Box<dyn DraftStrategy>>,
    rng: Rng,
}

impl DraftStrategy for ShuffledArms {
    fn name(&self) -> &'static str {
        "test-shuffled-arms"
    }

    fn propose(&mut self, seq: &[TokenId], k: usize, batch: &mut DraftBatch) {
        let i = self.rng.below(self.arms.len());
        self.arms[i].propose(seq, k, batch);
    }

    fn observe(&mut self, accepted: &[TokenId], model_out: &[TokenId]) {
        for a in &mut self.arms {
            a.observe(accepted, model_out);
        }
    }

    fn reset(&mut self) {
        for a in &mut self.arms {
            a.reset();
        }
    }
}

fn shuffled(c: &BenchCtx, seed: u64) -> Box<dyn DraftStrategy> {
    let arms = [
        StrategyName::Mixed,
        StrategyName::Context,
        StrategyName::ExtBigram,
        StrategyName::Session,
        StrategyName::Jacobi,
    ]
    .iter()
    .map(|&n| make_strategy(n, &c.tables, 1))
    .collect();
    Box::new(ShuffledArms { arms, rng: Rng::new(seed) })
}

/// Adaptive SpecDecoder output == greedy stream for randomized controller
/// configs and (k, w) caps.
#[test]
fn adaptive_specdecoder_is_lossless() {
    let c = ctx("small");
    let max_new = 24;
    let ps = prompts(&c);
    let want: Vec<Vec<u32>> = ps.iter().map(|p| greedy_stream(&c, p, max_new)).collect();
    let mut rng = Rng::new(0xADA9);
    for case in 0..6 {
        let cfg = random_cfg(&mut rng);
        let k_cap = *rng.choose(&[2usize, 5, 10, 20]);
        let w_cap = *rng.choose(&[2usize, 4, 10, 14]);
        for (i, (p, wanted)) in ps.iter().zip(&want).enumerate() {
            let ctl = controller(&c, cfg.clone());
            let mut dec = SpecDecoder::with_controller(
                &c.runtime,
                ctl,
                EngineConfig { k: k_cap, w: w_cap, q: 1, max_new_tokens: max_new },
            );
            let got = dec.generate(p).unwrap().tokens;
            assert_eq!(
                &got, wanted,
                "case {case} (k_cap {k_cap}, w_cap {w_cap}) prompt {i}: adaptive diverged"
            );
        }
    }
}

/// Even an adversarially random strategy trajectory (a different draft
/// source every step) cannot change the output stream.
#[test]
fn random_strategy_trajectories_are_lossless() {
    let c = ctx("small");
    let max_new = 20;
    let ps = prompts(&c);
    let mut rng = Rng::new(0x7E57);
    for (i, p) in ps.iter().enumerate() {
        let want = greedy_stream(&c, p, max_new);
        for rep in 0..2 {
            let k_cap = rng.range(1, 20);
            let w_cap = rng.range(0, 14);
            let mut dec = SpecDecoder::new(
                &c.runtime,
                shuffled(&c, rng.next_u64()),
                EngineConfig { k: k_cap, w: w_cap, q: 1, max_new_tokens: max_new },
            );
            let got = dec.generate(p).unwrap().tokens;
            assert_eq!(
                got, want,
                "prompt {i} rep {rep} (k {k_cap}, w {w_cap}): shuffled trajectory diverged"
            );
        }
    }
}

/// Batched engine with a MIXED population (adaptive, static, shuffled) at
/// concurrency 1/4/8 under a row budget: every stream byte-identical to
/// greedy, and no step ever packs more than the budget.
#[test]
fn adaptive_batched_is_lossless_and_respects_budget() {
    let c = ctx("small");
    let max_new = 20;
    let ps = prompts(&c);
    let want: Vec<Vec<u32>> = ps.iter().map(|p| greedy_stream(&c, p, max_new)).collect();
    let cfg = EngineConfig { k: 10, w: 10, q: 1, max_new_tokens: max_new };

    for conc in [1usize, 4, 8] {
        let budget = conc * 6; // >= lanes, well under conc * k
        let mut eng = BatchedEngine::with_budget(&c.runtime, conc, Some(budget));
        eng.collect_traces = true;
        let mut by_id: HashMap<ngrammys::engine::SeqId, usize> = HashMap::new();
        let mut results: Vec<Option<Vec<u32>>> = vec![None; ps.len()];
        let mut next = 0usize;
        let mut done = 0usize;
        while done < ps.len() {
            while eng.has_capacity() && next < ps.len() {
                let id = match next % 3 {
                    0 => eng
                        .admit_with(
                            &ps[next],
                            make_strategy(StrategyName::Mixed, &c.tables, 1),
                            Some(controller(&c, AdaptiveConfig::default())),
                            cfg.clone(),
                        )
                        .unwrap(),
                    1 => eng
                        .admit(
                            &ps[next],
                            make_strategy(StrategyName::Mixed, &c.tables, 1),
                            cfg.clone(),
                        )
                        .unwrap(),
                    _ => eng
                        .admit(&ps[next], shuffled(&c, next as u64), cfg.clone())
                        .unwrap(),
                };
                by_id.insert(id, next);
                next += 1;
            }
            for (id, r) in eng.step().unwrap() {
                results[by_id[&id]] = Some(r.tokens);
                done += 1;
            }
        }
        for (i, got) in results.iter().enumerate() {
            assert_eq!(
                got.as_ref().unwrap(),
                &want[i],
                "conc {conc} prompt {i}: batched adaptive stream diverged"
            );
        }

        // the row budget bounds the SUM of packed rows across each step's
        // calls (a ragged-depth step issues several)
        let mut per_step: HashMap<u64, usize> = HashMap::new();
        for t in &eng.packed_traces {
            *per_step.entry(t.step).or_insert(0) += t.rows;
        }
        assert!(!per_step.is_empty());
        for (&s, &rows) in &per_step {
            assert!(
                rows <= budget,
                "conc {conc} step {s}: packed {rows} rows > budget {budget}"
            );
        }
    }
}

/// The budget genuinely constrains packing: the same static workload
/// unbudgeted packs more rows per step than the budgeted cap allows.
#[test]
fn budget_caps_rows_below_unbudgeted_packing() {
    let c = ctx("small");
    let max_new = 16;
    let ps = prompts(&c);
    let cfg = EngineConfig { k: 10, w: 10, q: 1, max_new_tokens: max_new };
    let budget = 16usize; // 4 lanes x k=10 would pack up to 40 unbudgeted

    let run = |budget: Option<usize>| -> (usize, Vec<Vec<u32>>) {
        let mut eng = BatchedEngine::with_budget(&c.runtime, 4, budget);
        eng.collect_traces = true;
        let reqs: Vec<_> = ps
            .iter()
            .map(|p| (p.clone(), make_strategy(StrategyName::Mixed, &c.tables, 1), cfg.clone()))
            .collect();
        let results = ngrammys::engine::batched::generate_all(&mut eng, reqs).unwrap();
        let mut per_step: HashMap<u64, usize> = HashMap::new();
        for t in &eng.packed_traces {
            *per_step.entry(t.step).or_insert(0) += t.rows;
        }
        let max_rows = per_step.values().copied().max().unwrap_or(0);
        (max_rows, results.into_iter().map(|r| r.tokens).collect())
    };

    let (max_budgeted, toks_budgeted) = run(Some(budget));
    let (max_unbudgeted, toks_unbudgeted) = run(None);
    assert!(max_budgeted <= budget, "budgeted engine packed {max_budgeted} rows");
    assert!(
        max_unbudgeted > budget,
        "unbudgeted engine only packed {max_unbudgeted} rows — workload too small to test"
    );
    assert_eq!(toks_budgeted, toks_unbudgeted, "budgeting changed the streams");
}
