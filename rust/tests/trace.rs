//! Flight-recorder integration: tracing a live engine captures per-phase
//! step events with strategy provenance, a disabled hub records nothing,
//! and — THE invariant — attaching a recorder never perturbs the token
//! streams or the packed call schedule.

use ngrammys::bench::BenchCtx;
use ngrammys::config::EngineConfig;
use ngrammys::engine::{generate_all, BatchedEngine};
use ngrammys::scheduler::{make_strategy, StrategyName};
use ngrammys::trace::report::TraceSummary;
use ngrammys::trace::{
    to_jsonl, FlightRecorder, Phase, TraceEvent, TraceHub, DEFAULT_RING_CAPACITY,
};

fn ctx(model: &str) -> BenchCtx {
    BenchCtx::load(ngrammys::testkit::manifest(), model).unwrap()
}

fn prompts(c: &BenchCtx) -> Vec<Vec<u32>> {
    [
        "Question: Tom has 4 apples. Tom buys 2 more.",
        "def scale(x, y):\n    result",
        "User: What is the capital of France?",
        "Answer: Mia has 5 coins.",
    ]
    .iter()
    .map(|p| c.tokenizer.encode(p))
    .collect()
}

#[test]
fn recorder_captures_phase_events_from_a_live_engine() {
    let c = ctx("small");
    let cfg = EngineConfig { k: 5, w: 4, q: 1, max_new_tokens: 16 };
    let hub = TraceHub::new(DEFAULT_RING_CAPACITY);
    let rec = hub.recorder_for_engine(7);
    let mut eng = BatchedEngine::new(&c.runtime, 4);
    eng.recorder = Some(rec.clone());
    for p in prompts(&c) {
        let strat = make_strategy(StrategyName::Mixed, &c.tables, 1);
        eng.admit(&p, strat, cfg.clone()).unwrap();
    }
    while eng.active() > 0 {
        eng.step().unwrap();
    }
    assert!(rec.steps_recorded() > 0, "no step events recorded");

    let events = hub.recent(DEFAULT_RING_CAPACITY);
    let steps: Vec<_> = events
        .iter()
        .filter_map(|e| match e {
            TraceEvent::Step(s) => Some(*s),
            _ => None,
        })
        .collect();
    assert_eq!(steps.len() as u64, rec.steps_recorded());

    // every step carries the engine id and packed rows; the verify phase
    // (the model forward pass) must accumulate real time over the run,
    // and every step with committed sequences names a winning strategy
    let mut verify_us = 0u64;
    let mut wins = 0u64;
    for s in &steps {
        assert_eq!(s.engine, 7);
        assert!(s.rows > 0, "step event with no packed rows");
        verify_us += s.phase_us[Phase::Verify.index()];
        wins += s.wins.iter().map(|&w| w as u64).sum::<u64>();
    }
    assert!(verify_us > 0, "verify phase never accumulated time");
    assert!(wins > 0, "no strategy provenance recorded");

    // the summary sees the same totals, and the JSONL export round-trips
    let summary = TraceSummary::from_events(&events);
    assert_eq!(summary.steps, steps.len() as u64);
    assert_eq!(summary.phase_total_us[Phase::Verify.index()], verify_us);
    let reparsed = TraceSummary::from_jsonl(&to_jsonl(&events)).unwrap();
    assert_eq!(reparsed.steps, summary.steps);
    assert_eq!(reparsed.phase_total_us, summary.phase_total_us);
}

#[test]
fn disabled_hub_records_nothing() {
    let c = ctx("small");
    let hub = TraceHub::new(DEFAULT_RING_CAPACITY);
    hub.set_enabled(false);
    let rec = hub.recorder_for_engine(0);
    assert!(!rec.enabled());
    let cfg = EngineConfig { k: 5, w: 4, q: 1, max_new_tokens: 12 };
    let mut eng = BatchedEngine::new(&c.runtime, 2);
    eng.recorder = Some(rec.clone());
    let strat = make_strategy(StrategyName::Mixed, &c.tables, 1);
    eng.admit(&prompts(&c)[0], strat, cfg).unwrap();
    while eng.active() > 0 {
        eng.step().unwrap();
    }
    assert_eq!(rec.steps_recorded(), 0, "disabled recorder must be a no-op");
    assert!(hub.recent(16).is_empty());
}

/// The overhead invariant the CI smoke gate also pins: the same requests
/// decoded with and without a recorder produce byte-identical streams
/// and an identical packed call schedule.
#[test]
fn tracing_never_perturbs_token_streams() {
    let c = ctx("small");
    let cfg = EngineConfig { k: 10, w: 10, q: 1, max_new_tokens: 20 };
    let run = |recorder: Option<std::sync::Arc<FlightRecorder>>| {
        let mut eng = BatchedEngine::new(&c.runtime, 4);
        eng.collect_traces = true;
        eng.recorder = recorder;
        let reqs: Vec<_> = prompts(&c)
            .iter()
            .map(|p| (p.clone(), make_strategy(StrategyName::Mixed, &c.tables, 1), cfg.clone()))
            .collect();
        let out = generate_all(&mut eng, reqs).unwrap();
        let streams: Vec<Vec<u32>> = out.into_iter().map(|r| r.tokens).collect();
        let packed: Vec<(usize, usize, usize)> =
            eng.packed_traces.iter().map(|t| (t.rows, t.w, t.max_ctx)).collect();
        (streams, packed)
    };
    let rec = FlightRecorder::standalone(0, DEFAULT_RING_CAPACITY);
    let (traced, traced_packed) = run(Some(rec.clone()));
    let (untraced, untraced_packed) = run(None);
    assert_eq!(traced, untraced, "tracing perturbed the output streams");
    assert_eq!(traced_packed, untraced_packed, "tracing changed the packed call schedule");
    assert!(rec.steps_recorded() > 0, "traced run recorded nothing");
}
