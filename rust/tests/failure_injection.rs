//! Failure injection: corrupted or missing artifacts must surface as
//! actionable errors, never panics or silent misbehavior. Uses a scratch
//! copy of the artifact tree so the real one is untouched.

use std::fs;
use std::path::{Path, PathBuf};

use ngrammys::config::Manifest;
use ngrammys::draft::tables::Table;
use ngrammys::draft::NgramTables;
use ngrammys::runtime::ModelRuntime;
use ngrammys::tokenizer::BpeTokenizer;

struct Scratch(PathBuf);

impl Drop for Scratch {
    fn drop(&mut self) {
        let _ = fs::remove_dir_all(&self.0);
    }
}

/// Copy manifest + the `small` model dir + tokenizer into a temp tree.
fn scratch_tree(tag: &str) -> Scratch {
    let src = ngrammys::testkit::artifacts_dir();
    let dst = std::env::temp_dir().join(format!("ngrammys-failinj-{tag}-{}", std::process::id()));
    let _ = fs::remove_dir_all(&dst);
    fs::create_dir_all(dst.join("models/small")).unwrap();
    fs::create_dir_all(dst.join("data")).unwrap();
    for f in ["manifest.json", "tokenizer.json"] {
        fs::copy(src.join(f), dst.join(f)).unwrap();
    }
    for entry in fs::read_dir(src.join("models/small")).unwrap() {
        let e = entry.unwrap();
        fs::copy(e.path(), dst.join("models/small").join(e.file_name())).unwrap();
    }
    for entry in fs::read_dir(src.join("data")).unwrap() {
        let e = entry.unwrap();
        fs::copy(e.path(), dst.join("data").join(e.file_name())).unwrap();
    }
    Scratch(dst)
}

fn small_art(root: &Path) -> ngrammys::config::ModelArtifacts {
    Manifest::load(root).unwrap().model("small").unwrap().clone()
}

#[test]
fn truncated_params_bin_is_rejected() {
    let s = scratch_tree("params");
    let p = s.0.join("models/small/params.bin");
    let data = fs::read(&p).unwrap();
    fs::write(&p, &data[..data.len() / 2]).unwrap();
    let err = match ModelRuntime::load(&small_art(&s.0)) {
        Ok(_) => panic!("truncated params.bin accepted"),
        Err(e) => e,
    };
    assert!(err.to_string().contains("params.bin"), "{err:#}");
}

#[test]
fn corrupted_table_magic_is_rejected() {
    let s = scratch_tree("table");
    let p = s.0.join("models/small/bigram.bin");
    let mut data = fs::read(&p).unwrap();
    data[0] ^= 0xff;
    fs::write(&p, &data).unwrap();
    let err = NgramTables::load(&small_art(&s.0)).unwrap_err();
    assert!(format!("{err:#}").contains("magic"), "{err:#}");
}

#[test]
fn garbage_hlo_fails_at_compile_not_execute() {
    let s = scratch_tree("hlo");
    // find the (1, 0) step file and corrupt it
    let art = small_art(&s.0);
    let path = art.steps.get(&(1, 0)).unwrap();
    fs::write(path, "HloModule not_actually_hlo ENTRY {").unwrap();
    let rt = ModelRuntime::load(&art).unwrap();
    assert!(rt.warm_step(1, 0).is_err());
    // other shapes still work
    assert!(rt.warm_step(1, 1).is_ok());
}

#[test]
fn manifest_syntax_error_is_actionable() {
    let s = scratch_tree("manifest");
    fs::write(s.0.join("manifest.json"), "{\"version\": 1,,}").unwrap();
    let err = Manifest::load(&s.0).unwrap_err();
    assert!(format!("{err:#}").contains("json"), "{err:#}");
}

#[test]
fn manifest_missing_model_key_is_actionable() {
    let s = scratch_tree("key");
    let text = fs::read_to_string(s.0.join("manifest.json")).unwrap();
    let broken = text.replace("\"d_model\"", "\"d_model_gone\"");
    fs::write(s.0.join("manifest.json"), broken).unwrap();
    let err = Manifest::load(&s.0).unwrap_err();
    assert!(format!("{err:#}").contains("d_model"), "{err:#}");
}

#[test]
fn tokenizer_with_bad_merge_ids_is_rejected() {
    // merge 1 references id 300, which doesn't exist yet -> must error,
    // never panic (this test caught a real index-out-of-bounds)
    let err = BpeTokenizer::from_json_text(
        r#"{"type": "byte_bpe", "vocab_size": 258, "merges": [[104, 101], [300, 108]]}"#,
    )
    .unwrap_err();
    assert!(err.to_string().contains("300"), "{err:#}");
    // forward references are also invalid
    assert!(BpeTokenizer::from_json_text(
        r#"{"type": "byte_bpe", "vocab_size": 258, "merges": [[257, 101]]}"#,
    )
    .is_err());
    // decode with out-of-range ids must be safe
    let tok = BpeTokenizer::from_merges(vec![(104, 101)]);
    let _ = tok.decode(&[0, 256, 9999]);
}

#[test]
fn table_shape_mismatch_detected_against_manifest() {
    let s = scratch_tree("shape");
    // overwrite bigram with a wrong-rows table
    let small = Table::from_data(4, 2, 1, vec![0, 1, 1, 2, 2, 3, 3, 0]);
    let mut bytes = Vec::new();
    for v in [ngrammys::draft::tables::MAGIC, 4, 2, 1] {
        bytes.extend_from_slice(&v.to_le_bytes());
    }
    for r in 0..4 {
        for c in 0..2 {
            bytes.extend_from_slice(&small.at(r, c).to_le_bytes());
        }
    }
    fs::write(s.0.join("models/small/bigram.bin"), bytes).unwrap();
    let err = NgramTables::load(&small_art(&s.0)).unwrap_err();
    assert!(format!("{err:#}").contains("rows"), "{err:#}");
}
