//! Fleet-shared draft store invariants (`--shared-draft fleet`): sharing
//! accepted-token chains across engines and requests may only change
//! WHICH candidates are proposed, never the accepted greedy stream.
//! Byte-identity between `off` and `fleet` modes is pinned at
//! concurrency 1/4/8 over two waves of the same mixed traffic (wave 1
//! seeds the store, wave 2 harvests it — the regime the store exists
//! for), every stream is checked against per-sequence greedy decoding,
//! and the store counters must show real publishes and — on the
//! sequential path, where ordering is deterministic — real hits.

use std::sync::atomic::Ordering;

use ngrammys::bench::BenchCtx;
use ngrammys::config::{EngineConfig, ServeConfig, SharedDraft};
use ngrammys::engine::{greedy_config, NoDraft, SpecDecoder};
use ngrammys::scheduler::{GenRequest, Scheduler, StrategyName};

fn ctx(model: &str) -> BenchCtx {
    BenchCtx::load(ngrammys::testkit::manifest(), model).unwrap()
}

fn greedy_stream(c: &BenchCtx, prompt: &[u32], max_new: usize) -> Vec<u32> {
    let mut dec = SpecDecoder::new(&c.runtime, Box::new(NoDraft), greedy_config(max_new));
    dec.generate(prompt).unwrap().tokens
}

const TEXTS: [&str; 6] = [
    "Question: Tom has 4 apples. Tom buys 2 more.",
    "def scale(x, y):\n    result",
    "User: What is the capital of France?",
    "Question: Tom has 4 apples. Tom buys 2 more.",
    "def blend(value, count):",
    "User: Tell me about ancient rivers.",
];

/// Mixed traffic that exercises every shared-store path: session-cache
/// requests (the wrapped-strategy row-injection path), adaptive requests
/// (the fingerprint-prior seeding path) and greedy w = 0 requests (which
/// must stay untouched padding-wise).
fn req(c: &BenchCtx, text: &str, i: usize, max_new: usize) -> GenRequest {
    let strategy = match i % 3 {
        0 => StrategyName::Session,
        1 => StrategyName::Adaptive,
        _ => StrategyName::None,
    };
    let greedy = strategy == StrategyName::None;
    GenRequest {
        prompt: c.tokenizer.encode(text),
        engine: EngineConfig {
            k: if greedy { 1 } else { 10 },
            w: if greedy { 0 } else { 10 },
            q: 1,
            max_new_tokens: max_new,
        },
        strategy,
    }
}

/// Serve TWO waves of the same requests and return every stream in submit
/// order plus the final (hits, publishes) counters after shutdown — the
/// post-join mirror must account every Drop-flushed tail.
fn serve_waves(c: &BenchCtx, cfg: &ServeConfig, max_new: usize) -> (Vec<Vec<u32>>, u64, u64) {
    let sched = Scheduler::start(&ngrammys::testkit::manifest(), "small", cfg).unwrap();
    let mut streams = Vec::new();
    for _wave in 0..2 {
        let rxs: Vec<_> = TEXTS
            .iter()
            .enumerate()
            .map(|(i, t)| sched.submit(req(c, t, i, max_new)).unwrap())
            .collect();
        for rx in rxs {
            streams.push(rx.recv().unwrap().unwrap().tokens);
        }
    }
    let metrics = sched.metrics.clone();
    sched.shutdown();
    (
        streams,
        metrics.shared_draft_hits.load(Ordering::Relaxed),
        metrics.shared_draft_publishes.load(Ordering::Relaxed),
    )
}

/// The differential pin: `--shared-draft off` vs `fleet` at concurrency
/// 1 (per-sequence workers), 4 and 8 (work-stealing multi-engine pool)
/// produce byte-identical streams, all equal to per-sequence greedy
/// decoding.
#[test]
fn fleet_sharing_is_byte_identical_across_concurrency() {
    let c = ctx("small");
    let max_new = 12;
    let want: Vec<Vec<u32>> = TEXTS
        .iter()
        .enumerate()
        .map(|(i, t)| greedy_stream(&c, &req(&c, t, i, max_new).prompt, max_new))
        .collect();

    for conc in [1usize, 4, 8] {
        let mk = |mode: SharedDraft| ServeConfig {
            addr: "127.0.0.1:0".into(),
            queue_cap: 64,
            batch: conc,
            engines: 2,
            shared_draft: mode,
            ..ServeConfig::default()
        };
        let (off, _, off_pub) = serve_waves(&c, &mk(SharedDraft::Off), max_new);
        let (fleet, fleet_hits, fleet_pub) = serve_waves(&c, &mk(SharedDraft::Fleet), max_new);
        assert_eq!(
            off, fleet,
            "concurrency {conc}: fleet sharing changed an output stream"
        );
        for (i, got) in fleet.iter().enumerate() {
            assert_eq!(
                got,
                &want[i % TEXTS.len()],
                "concurrency {conc} stream {i} diverged from per-sequence greedy"
            );
        }
        assert_eq!(off_pub, 0, "concurrency {conc}: off mode must never touch a store");
        assert!(
            fleet_pub > 0,
            "concurrency {conc}: fleet mode published no accepted-token deltas"
        );
        if conc == 1 {
            // sequential workers publish each request's tail before the
            // next request proposes, so wave 2 must hit wave 1's chains
            assert!(fleet_hits > 0, "sequential fleet run never hit the store");
        }
    }
}
