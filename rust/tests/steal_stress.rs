//! Multi-thread stress for the work-stealing dispatch queues
//! (`scheduler::steal::WorkQueues`): submit/steal/retire churn across
//! racing producers and consumers must lose no job and execute none
//! twice, and every queue must keep the documented scored admission
//! policy — best score first, ties FIFO by arrival, with the
//! anti-starvation override for the oldest waiter.

use std::collections::HashSet;
use std::sync::atomic::{AtomicUsize, Ordering};
use std::sync::Arc;
use std::thread;
use std::time::Duration;

use ngrammys::scheduler::steal::PushError;
use ngrammys::scheduler::WorkQueues;

const QUEUES: usize = 4;
const PRODUCERS: usize = 4;
const PER_PRODUCER: usize = 400;
const TOTAL: usize = PRODUCERS * PER_PRODUCER;

/// Full-churn run: 4 producers spin-push 1600 unique jobs through a
/// 64-entry shared cap (so backpressure fires constantly) while 4
/// consumers race own-queue pops against cross-queue steals. Every job
/// must come out exactly once, and a closed structure must hand new
/// work back untouched.
#[test]
fn churn_loses_and_duplicates_no_job() {
    let q = Arc::new(WorkQueues::<u64>::new(QUEUES, 64));
    let done = Arc::new(AtomicUsize::new(0));

    let mut producers = Vec::new();
    for p in 0..PRODUCERS {
        let q = q.clone();
        producers.push(thread::spawn(move || {
            for n in 0..PER_PRODUCER {
                let id = (p * PER_PRODUCER + n) as u64;
                let mut item = id;
                loop {
                    // cap rejections hand the item back: retry until a
                    // consumer frees shared capacity
                    match q.push((id as usize) % QUEUES, item, (id % 5) as f64) {
                        Ok(()) => break,
                        Err(PushError::Full(back)) => {
                            item = back;
                            thread::yield_now();
                        }
                        Err(PushError::Closed(_)) => panic!("queues closed mid-run"),
                    }
                }
            }
        }));
    }

    let mut consumers = Vec::new();
    for w in 0..QUEUES {
        let q = q.clone();
        let done = done.clone();
        consumers.push(thread::spawn(move || {
            let mut seen = Vec::new();
            while done.load(Ordering::SeqCst) < TOTAL {
                let got = q
                    .pop_where(w, |_| true)
                    .map(|(id, _, _)| id)
                    .or_else(|| q.steal_where(w, |_| true).map(|(_, id, _, _)| id));
                match got {
                    Some(id) => {
                        seen.push(id);
                        done.fetch_add(1, Ordering::SeqCst);
                    }
                    None => q.wait_for_work(Duration::from_millis(1)),
                }
            }
            seen
        }));
    }

    for h in producers {
        h.join().unwrap();
    }
    let mut all = Vec::new();
    for h in consumers {
        all.extend(h.join().unwrap());
    }

    assert_eq!(all.len(), TOTAL, "a job was lost or double-executed");
    let unique: HashSet<u64> = all.iter().copied().collect();
    assert_eq!(unique.len(), TOTAL, "a job was executed twice");
    assert!(unique.iter().all(|&id| (id as usize) < TOTAL));
    assert!(q.is_empty(), "entries left behind after full drain");

    // retire: a closed structure rejects new work (item handed back) and
    // has nothing left to drain
    q.close();
    match q.push(0, 7, 1.0) {
        Err(PushError::Closed(7)) => {}
        other => panic!("push after close returned {other:?}"),
    }
    assert!(q.drain_all().is_empty());
}

/// Scored-ordering pin: after racing producers finish, one drainer per
/// queue pops ONLY its own queue, so its log order IS that queue's pop
/// order. Replaying the log against the documented policy, every pop
/// must take either the best-scored remaining entry (ties FIFO by
/// arrival stamp) or — under the anti-starvation bound — the oldest
/// remaining entry.
#[test]
fn own_queue_drain_follows_scored_policy_per_queue() {
    const Q: usize = 3;
    const PER_QUEUE: usize = 64;
    let q = Arc::new(WorkQueues::<u64>::new(Q, Q * PER_QUEUE));

    // one producer per queue: producers race each other, but each
    // queue's arrival order stays deterministic
    let mut producers = Vec::new();
    for i in 0..Q {
        let q = q.clone();
        producers.push(thread::spawn(move || {
            for n in 0..PER_QUEUE {
                let id = (i * PER_QUEUE + n) as u64;
                q.push(i, id, (id % 5) as f64).unwrap();
            }
        }));
    }
    for h in producers {
        h.join().unwrap();
    }

    let mut drainers = Vec::new();
    for i in 0..Q {
        let q = q.clone();
        drainers.push(thread::spawn(move || {
            let mut log = Vec::new();
            while let Some(hit) = q.pop_where(i, |_| true) {
                log.push(hit);
            }
            log
        }));
    }
    for (i, h) in drainers.into_iter().enumerate() {
        let log: Vec<(u64, f64, u64)> = h.join().unwrap();
        assert_eq!(log.len(), PER_QUEUE, "queue {i} lost an entry");
        let mut remaining = log.clone();
        remaining.sort_by_key(|e| e.2); // by arrival stamp: [0] is oldest
        for (_, score, seq) in &log {
            let best = remaining
                .iter()
                .cloned()
                .max_by(|a, b| a.1.partial_cmp(&b.1).unwrap().then(b.2.cmp(&a.2)))
                .unwrap();
            let oldest = remaining[0];
            assert!(
                *seq == best.2 || *seq == oldest.2,
                "queue {i}: popped seq {seq} (score {score}) is neither the best \
                 remaining (seq {}) nor the starving oldest (seq {})",
                best.2,
                oldest.2
            );
            let at = remaining.iter().position(|e| e.2 == *seq).unwrap();
            remaining.remove(at);
        }
    }
    // cycling scores force younger high-score entries past older ones,
    // so the reorder accounting must have registered some
    assert!(q.reorders() > 0, "mixed scores produced no reorders");
}

/// The shared cap is global across queues: a push bounced with `Full`
/// gets its item back, and a pop on ANY queue frees capacity.
#[test]
fn shared_cap_backpressure_hands_items_back() {
    let q = WorkQueues::<u64>::new(2, 2);
    q.push(0, 1, 0.0).unwrap();
    q.push(1, 2, 0.0).unwrap();
    match q.push(0, 3, 0.0) {
        Err(PushError::Full(3)) => {}
        other => panic!("expected Full(3), got {other:?}"),
    }
    assert_eq!(q.pop_where(1, |_| true).map(|(id, _, _)| id), Some(2));
    q.push(0, 3, 0.0).unwrap();
    assert_eq!(q.len(), 2);
}
