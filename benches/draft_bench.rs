//! Micro-benchmarks for the negligible-cost claim (paper §4): drafting must
//! be orders of magnitude cheaper than a model call. Uses the in-repo
//! bench harness (criterion is unavailable offline).
//!
//!     cargo bench --bench draft_bench
//!
//! The batches are created once and `reset` per iteration — the engines'
//! steady-state pattern, so these numbers reflect the allocation-free
//! arena path (see also `ngrammys bench draft` for the incremental-vs-
//! rescan comparison and the CI-gated summary).

use std::sync::Arc;

use ngrammys::draft::tables::Table;
use ngrammys::draft::{
    ContextNgram, DraftBatch, DraftStrategy, ExtendedBigram, JacobiDraft, MixedStrategy,
    NgramTables,
};
use ngrammys::engine::acceptance;
use ngrammys::util::bench::{black_box, Bencher};
use ngrammys::util::prop;
use ngrammys::util::rng::Rng;

fn synthetic_tables(vocab: usize, topk: usize, depth: usize) -> Arc<NgramTables> {
    let bigram = Table::from_data(
        vocab, topk, 1,
        (0..vocab as u32)
            .flat_map(|x| (1..=topk as u32).map(move |j| (x + j) % vocab as u32))
            .collect(),
    );
    let unigram = Table::from_data(1, topk, 1, (0..topk as u32).collect());
    let ext = Table::from_data(
        vocab, topk, depth,
        (0..vocab as u32)
            .flat_map(|x| {
                (1..=topk as u32).flat_map(move |j| {
                    (0..depth as u32).map(move |d| (x + j + d) % vocab as u32)
                })
            })
            .collect(),
    );
    Arc::new(NgramTables { bigram, unigram, ext_bigram: ext })
}

fn main() {
    let mut rng = Rng::new(7);
    // a realistic decode-time sequence: 400 tokens with heavy repetition
    let mut seq = prop::vec_u32(&mut rng, 120, 0..512);
    while seq.len() < 400 {
        let start = rng.below(seq.len() - 20);
        let n = rng.range(4, 16);
        let repeat: Vec<u32> = seq[start..start + n].to_vec();
        seq.extend(repeat);
    }
    let tables = synthetic_tables(512, 32, 16);

    println!("== draft-strategy micro-benches (paper: draft cost must be ~0) ==");
    println!("   reference: one verification call on this host is ~10-100 ms\n");
    let mut b = Bencher::default();

    let mut ctx = ContextNgram::new(1);
    let mut batch = DraftBatch::new(10);
    b.bench("context-ngram propose (q=1, len=400, k=10, w=10)", || {
        batch.reset(10);
        ctx.propose(black_box(&seq), 10, &mut batch);
        black_box(batch.k());
    });

    let mut ctx2 = ContextNgram::new(2);
    b.bench("context-ngram propose (q=2)", || {
        batch.reset(10);
        ctx2.propose(black_box(&seq), 10, &mut batch);
        black_box(batch.k());
    });

    let mut big = ExtendedBigram::new(tables.clone());
    b.bench("ext-bigram propose (k=10, w=10)", || {
        batch.reset(10);
        big.propose(black_box(&seq), 10, &mut batch);
        black_box(batch.k());
    });

    let mut mixed = MixedStrategy::paper(tables.clone(), 1);
    b.bench("mixed propose (k=10, w=10)", || {
        batch.reset(10);
        mixed.propose(black_box(&seq), 10, &mut batch);
        black_box(batch.k());
    });

    let mut mixed25 = MixedStrategy::paper(tables.clone(), 1);
    b.bench("mixed propose (k=25, w=14)", || {
        batch.reset(14);
        mixed25.propose(black_box(&seq), 25, &mut batch);
        black_box(batch.k());
    });

    let mut jac = JacobiDraft::new(0);
    jac.observe(&[1, 2], &[1, 2, 3, 4, 5, 6, 7, 8, 9, 10, 11]);
    b.bench("jacobi propose (k=1, w=10)", || {
        batch.reset(10);
        jac.propose(black_box(&seq), 1, &mut batch);
        black_box(batch.k());
    });

    // acceptance judging
    let mut judged = DraftBatch::new(10);
    mixed.propose(&seq, 10, &mut judged);
    while judged.k() < 10 {
        judged.push(vec![0; 10], ngrammys::draft::StrategyKind::Empty, 0);
    }
    let out: Vec<u32> = prop::vec_u32(&mut rng, 10 * 11, 0..512);
    b.bench("acceptance judge (k=10, w=10)", || {
        black_box(acceptance::judge(black_box(&judged), black_box(&out), 11));
    });

    println!("\nAll drafting costs should be in the ns-µs range — negligible");
    println!("against a model call, which is the paper's core premise (P1-P3).");
}
