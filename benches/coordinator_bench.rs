//! End-to-end coordinator benchmarks against the REAL artifacts: per-call
//! verification latency across (k, w) shapes (the measured counterpart of
//! Fig. 1), prefill latency per bucket, KV-commit cost, and the paper's
//! Table-1 cells in miniature.
//!
//!     cargo bench --bench coordinator_bench
//!
//! Requires `make artifacts` to have run.

use ngrammys::bench::BenchCtx;
use ngrammys::config::{default_artifacts_dir, EngineConfig, Manifest};
use ngrammys::engine::batched::generate_all;
use ngrammys::engine::BatchedEngine;
use ngrammys::kvcache::SharedKvCache;
use ngrammys::scheduler::{make_strategy, StrategyName};
use ngrammys::util::bench::{black_box, Bencher};

fn main() {
    let manifest = match Manifest::load(&default_artifacts_dir()) {
        Ok(m) => m,
        Err(e) => {
            eprintln!("SKIP coordinator_bench: {e:#} (run `make artifacts`)");
            return;
        }
    };
    let ctx = BenchCtx::load(manifest, "base").expect("loading model");
    let dims = ctx.runtime.artifacts().dims.clone();

    let mut cache = SharedKvCache::new(dims.n_layers, dims.max_len, dims.n_heads, dims.head_dim);
    cache.len = 100;

    println!("== verification-call latency by (k, w), ctx_len=100, model 'base' ==");
    let mut b = Bencher::quick();
    for (k, w) in [(1, 0), (1, 4), (5, 4), (10, 10), (25, 14)] {
        ctx.runtime.warm_step(k, w).unwrap();
        let tokens = vec![1u32; k * (w + 1)];
        b.bench(&format!("spec_step k={k:<2} w={w:<2}"), || {
            black_box(ctx.runtime.spec_step(k, w, &tokens, &cache).unwrap());
        });
    }

    println!("\n== prefill latency by bucket ==");
    for bucket in [64usize, 128, 256] {
        ctx.runtime.warm_prefill(bucket).unwrap();
        let prompt = vec![1u32; bucket - 4];
        b.bench(&format!("prefill p={bucket}"), || {
            let mut c = SharedKvCache::new(
                dims.n_layers, dims.max_len, dims.n_heads, dims.head_dim);
            black_box(ctx.runtime.prefill(&prompt, &mut c).unwrap());
        });
    }

    println!("\n== KV commit (host memcpy) ==");
    let (k, w1) = (10usize, 11usize);
    let n = dims.n_layers * k * w1 * dims.n_heads * dims.head_dim;
    let k_tail = vec![0.5f32; n];
    let v_tail = vec![0.25f32; n];
    b.bench("kvcache commit_tail (k=10, w=10, 11 positions)", || {
        let mut c = cache.clone();
        c.commit_tail(black_box(&k_tail), &v_tail, k, w1, 3, w1).unwrap();
        black_box(c.len);
    });

    println!("\n== end-to-end generation (one Table-1 cell in miniature) ==");
    let prompts = ctx.prompts("code", 2, 96).unwrap();
    let mut slow = Bencher::quick();
    slow.target = std::time::Duration::from_millis(1500);
    for (label, strat, k, w) in [
        ("greedy (1,0)", StrategyName::None, 1, 0),
        ("mixed (10,10)", StrategyName::Mixed, 10, 10),
    ] {
        slow.bench(&format!("generate 24 tok, {label}"), || {
            let c = ngrammys::bench::run_cell(
                &ctx, strat, &prompts[..1], k, w, 1, 24).unwrap();
            black_box(c.total_tokens);
        });
    }

    println!("\n== cross-request batching: aggregate throughput by concurrency ==");
    println!("   (sim = A100 cost model over the run's real packed-call traces;");
    println!("    the batched engine's packed call reads weights once per step)");
    let reqs = ctx.prompts("code", 8, 96).unwrap();
    let cm = ctx.cost_model();
    let cfg = EngineConfig { k: 10, w: 10, q: 1, max_new_tokens: 24 };
    for conc in [1usize, 4, 8] {
        let t0 = std::time::Instant::now();
        let mut eng = BatchedEngine::new(&ctx.runtime, conc);
        eng.collect_traces = true;
        let requests: Vec<_> = reqs
            .iter()
            .map(|p| {
                let s = make_strategy(StrategyName::Mixed, &ctx.tables, 1);
                (p.tokens.clone(), s, cfg.clone())
            })
            .collect();
        let results = generate_all(&mut eng, requests).unwrap();
        let tokens: usize = results.iter().map(|r| r.tokens.len() - 1).sum();
        let sim_s: f64 = eng
            .packed_traces
            .iter()
            .map(|p| cm.call_time(p.rows, p.w + 1, p.max_ctx))
            .sum();
        println!(
            "   conc={conc:<2} packed_calls={:<4} sim {:>9.1} tok/s   cpu {:>9.1} tok/s",
            eng.packed_traces.len(),
            tokens as f64 / sim_s,
            tokens as f64 / t0.elapsed().as_secs_f64(),
        );
    }
}
