//! End-to-end coordinator benchmarks against the REAL artifacts: per-call
//! verification latency across (k, w) shapes (the measured counterpart of
//! Fig. 1), prefill latency per bucket, KV-commit cost, and the paper's
//! Table-1 cells in miniature.
//!
//!     cargo bench --bench coordinator_bench
//!
//! Requires `make artifacts` to have run.

use ngrammys::bench::BenchCtx;
use ngrammys::config::{default_artifacts_dir, Manifest};
use ngrammys::kvcache::SharedKvCache;
use ngrammys::scheduler::StrategyName;
use ngrammys::util::bench::{black_box, Bencher};

fn main() {
    let manifest = match Manifest::load(&default_artifacts_dir()) {
        Ok(m) => m,
        Err(e) => {
            eprintln!("SKIP coordinator_bench: {e:#} (run `make artifacts`)");
            return;
        }
    };
    let ctx = BenchCtx::load(manifest, "base").expect("loading model");
    let dims = ctx.runtime.artifacts().dims.clone();

    let mut cache = SharedKvCache::new(dims.n_layers, dims.max_len, dims.n_heads, dims.head_dim);
    cache.len = 100;

    println!("== verification-call latency by (k, w), ctx_len=100, model 'base' ==");
    let mut b = Bencher::quick();
    for (k, w) in [(1, 0), (1, 4), (5, 4), (10, 10), (25, 14)] {
        ctx.runtime.warm_step(k, w).unwrap();
        let tokens = vec![1u32; k * (w + 1)];
        b.bench(&format!("spec_step k={k:<2} w={w:<2}"), || {
            black_box(ctx.runtime.spec_step(k, w, &tokens, &cache).unwrap());
        });
    }

    println!("\n== prefill latency by bucket ==");
    for bucket in [64usize, 128, 256] {
        ctx.runtime.warm_prefill(bucket).unwrap();
        let prompt = vec![1u32; bucket - 4];
        b.bench(&format!("prefill p={bucket}"), || {
            let mut c = SharedKvCache::new(
                dims.n_layers, dims.max_len, dims.n_heads, dims.head_dim);
            black_box(ctx.runtime.prefill(&prompt, &mut c).unwrap());
        });
    }

    println!("\n== KV commit (host memcpy) ==");
    let (k, w1) = (10usize, 11usize);
    let n = dims.n_layers * k * w1 * dims.n_heads * dims.head_dim;
    let k_tail = vec![0.5f32; n];
    let v_tail = vec![0.25f32; n];
    b.bench("kvcache commit_tail (k=10, w=10, 11 positions)", || {
        let mut c = cache.clone();
        c.commit_tail(black_box(&k_tail), &v_tail, k, w1, 3, w1).unwrap();
        black_box(c.len);
    });

    println!("\n== end-to-end generation (one Table-1 cell in miniature) ==");
    let prompts = ctx.prompts("code", 2, 96).unwrap();
    let mut slow = Bencher::quick();
    slow.target = std::time::Duration::from_millis(1500);
    for (label, strat, k, w) in [
        ("greedy (1,0)", StrategyName::None, 1, 0),
        ("mixed (10,10)", StrategyName::Mixed, 10, 10),
    ] {
        slow.bench(&format!("generate 24 tok, {label}"), || {
            let c = ngrammys::bench::run_cell(
                &ctx, strat, &prompts[..1], k, w, 1, 24).unwrap();
            black_box(c.total_tokens);
        });
    }
}
