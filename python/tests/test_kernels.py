"""L1 correctness: Pallas kernels vs pure-jnp oracles, swept over
shapes/dtypes with hypothesis. This is the CORE kernel signal."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from compile.kernels import attention as A
from compile.kernels import ref as R

jax.config.update("jax_platform_name", "cpu")


def rand(rng, shape, dtype):
    x = rng.normal(size=shape).astype(np.float32)
    return jnp.asarray(x, dtype)


@settings(max_examples=20, deadline=None)
@given(
    rows=st.integers(1, 40),
    heads=st.integers(1, 4),
    hd=st.sampled_from([8, 16, 32]),
    ntiles=st.integers(1, 4),
    block=st.sampled_from([32, 64, 128]),
    frac=st.floats(0.0, 1.0),
    dtype=st.sampled_from(["float32", "bfloat16"]),
    seed=st.integers(0, 2**16),
)
def test_ctx_attention_matches_ref(rows, heads, hd, ntiles, block, frac, dtype, seed):
    L = ntiles * block
    ctx_len = int(round(frac * L))
    rng = np.random.default_rng(seed)
    dt = jnp.float32 if dtype == "float32" else jnp.bfloat16
    q = rand(rng, (rows, heads, hd), dt)
    kc = rand(rng, (L, heads, hd), dt)
    vc = rand(rng, (L, heads, hd), dt)
    out, m, l = A.ctx_attention(q, kc, vc, jnp.int32(ctx_len), block_l=block)
    out_r, m_r, l_r = R.ctx_attention_ref(q, kc, vc, ctx_len)
    tol = 2e-4 if dtype == "float32" else 3e-2
    np.testing.assert_allclose(np.asarray(out), np.asarray(out_r), rtol=tol, atol=tol)
    np.testing.assert_allclose(np.asarray(m), np.asarray(m_r), rtol=tol, atol=tol)
    np.testing.assert_allclose(np.asarray(l), np.asarray(l_r), rtol=tol, atol=tol)


def test_ctx_attention_empty_cache_is_zero():
    rng = np.random.default_rng(0)
    q = rand(rng, (4, 2, 16), jnp.float32)
    kc = rand(rng, (128, 2, 16), jnp.float32)
    vc = rand(rng, (128, 2, 16), jnp.float32)
    out, m, l = A.ctx_attention(q, kc, vc, jnp.int32(0))
    assert np.all(np.asarray(l) == 0.0)
    assert np.all(np.asarray(out) == 0.0)


@settings(max_examples=25, deadline=None)
@given(
    lead=st.integers(1, 6),
    rows=st.integers(1, 8),
    d=st.sampled_from([8, 32, 96, 128]),
    dtype=st.sampled_from(["float32", "bfloat16"]),
    seed=st.integers(0, 2**16),
)
def test_rmsnorm_matches_ref(lead, rows, d, dtype, seed):
    rng = np.random.default_rng(seed)
    dt = jnp.float32 if dtype == "float32" else jnp.bfloat16
    x = rand(rng, (lead, rows, d), dt)
    s = rand(rng, (d,), dt)
    got = A.rmsnorm(x, s)
    want = R.rmsnorm_ref(x, s)
    tol = 1e-5 if dtype == "float32" else 3e-2
    np.testing.assert_allclose(
        np.asarray(got, np.float32), np.asarray(want, np.float32), rtol=tol, atol=tol)


@settings(max_examples=15, deadline=None)
@given(
    b=st.integers(1, 6),
    w1=st.integers(1, 6),
    heads=st.integers(1, 3),
    hd=st.sampled_from([8, 16]),
    ctx_len=st.integers(0, 128),
    seed=st.integers(0, 2**16),
)
def test_partition_merge_equals_full_attention(b, w1, heads, hd, ctx_len, seed):
    """ctx kernel + jnp tail + merge == dense oracle over the full window —
    the bifurcated-attention identity used by the model."""
    L = 128
    rng = np.random.default_rng(seed)
    q = rand(rng, (b, w1, heads, hd), jnp.float32)
    kc = rand(rng, (L, heads, hd), jnp.float32)
    vc = rand(rng, (L, heads, hd), jnp.float32)
    kt = rand(rng, (b, w1, heads, hd), jnp.float32)
    vt = rand(rng, (b, w1, heads, hd), jnp.float32)

    want = R.spec_attention_ref(q, kc, vc, ctx_len, kt, vt)

    o_ctx, m_ctx, l_ctx = A.ctx_attention(
        q.reshape(b * w1, heads, hd), kc, vc, jnp.int32(ctx_len))
    o_ctx = o_ctx.reshape(b, w1, heads, hd)
    m_ctx = m_ctx.reshape(b, w1, heads)
    l_ctx = l_ctx.reshape(b, w1, heads)
    scale = 1.0 / np.sqrt(hd)
    causal = jnp.arange(w1)[:, None] >= jnp.arange(w1)[None, :]
    sc = jnp.einsum("bqhd,bkhd->bqhk", q, kt) * scale
    sc = jnp.where(causal[None, :, None, :], sc, -jnp.inf)
    m_tail = jnp.max(sc, axis=-1)
    p = jnp.where(causal[None, :, None, :], jnp.exp(sc - m_tail[..., None]), 0.0)
    l_tail = jnp.sum(p, axis=-1)
    o_tail = jnp.einsum("bqhk,bkhd->bqhd", p, vt)
    got = A.merge_partitions(o_ctx, m_ctx, l_ctx, o_tail, m_tail, l_tail)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want), rtol=3e-4, atol=3e-4)


def test_block_size_invariance():
    """Same numerics for any context tile size (pure scheduling knob)."""
    rng = np.random.default_rng(3)
    q = rand(rng, (8, 2, 16), jnp.float32)
    kc = rand(rng, (256, 2, 16), jnp.float32)
    vc = rand(rng, (256, 2, 16), jnp.float32)
    ref_out = None
    for block in [32, 64, 128, 256]:
        out, m, l = A.ctx_attention(q, kc, vc, jnp.int32(200), block_l=block)
        if ref_out is None:
            ref_out = (out, m, l)
        else:
            np.testing.assert_allclose(out, ref_out[0], rtol=1e-5, atol=1e-5)
            np.testing.assert_allclose(m, ref_out[1], rtol=1e-6, atol=1e-6)
            np.testing.assert_allclose(l, ref_out[2], rtol=1e-5, atol=1e-5)
