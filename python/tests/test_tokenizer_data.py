"""Tokenizer (BPE) and corpus-generator tests, including hypothesis
round-trip sweeps — the python half of the cross-language parity contract
(rust/tests/tokenizer_parity.rs is the other half)."""

import random

import pytest
from hypothesis import given, settings, strategies as st

from compile import data as D
from compile.tokenizer import BpeTokenizer, split_pieces, train_bpe


@st.composite
def texts(draw):
    alphabet = st.sampled_from(list("ab cd\n\te.12:()é"))
    return "".join(draw(st.lists(alphabet, max_size=120)))


@settings(max_examples=150, deadline=None)
@given(texts())
def test_pieces_reassemble_exactly(text):
    data = text.encode("utf-8")
    assert b"".join(split_pieces(data)) == data


@settings(max_examples=80, deadline=None)
@given(texts())
def test_trained_tokenizer_roundtrip(text):
    tok = _tok()
    ids = tok.encode(text)
    assert tok.decode(ids) == text
    assert all(0 <= i < tok.vocab_size for i in ids)


_CACHED = {}


def _tok():
    if "t" not in _CACHED:
        corpus = "the cat sat on the mat. " * 50 + "def f(x):\n    return x\n" * 30
        _CACHED["t"] = train_bpe(corpus, 300)
    return _CACHED["t"]


def test_training_compresses_training_text():
    tok = _tok()
    text = "the cat sat on the mat."
    ids = tok.encode(text)
    assert len(ids) < len(text) / 2
    assert tok.decode(ids) == text


def test_json_roundtrip_preserves_encoding():
    tok = _tok()
    tok2 = BpeTokenizer.from_json(tok.to_json())
    for t in ["the mat", "def f(x):", "unseen zzz"]:
        assert tok.encode(t) == tok2.encode(t)


def test_merges_never_cross_piece_boundaries():
    tok = _tok()
    # encode("a b") must equal encode("a") + encode(" b")
    assert tok.encode("the cat") == tok.encode("the") + tok.encode(" cat")


def test_empty_and_whitespace():
    tok = _tok()
    assert tok.encode("") == []
    for s in [" ", "  ", "\n", " \n "]:
        assert tok.decode(tok.encode(s)) == s


# ---------------------------------------------------------------------------
# corpus generators

def test_generators_are_deterministic():
    a = D.gen_math(random.Random(5), 10)
    b = D.gen_math(random.Random(5), 10)
    assert a == b


def test_math_answers_are_arithmetically_correct():
    for ex in D.gen_math(random.Random(1), 200):
        # "a OP b = c" spans must be correct arithmetic
        for line in ex.splitlines():
            for frag in line.split(". "):
                if " = " in frag and any(op in frag for op in [" + ", " - ", " * "]):
                    expr = frag.split(" = ")
                    lhs, rhs = expr[0], expr[1]
                    rhs_num = int("".join(ch for ch in rhs.split()[0] if ch.isdigit()))
                    for op, f in [(" + ", lambda x, y: x + y),
                                  (" - ", lambda x, y: x - y),
                                  (" * ", lambda x, y: x * y)]:
                        if op in lhs:
                            x, y = lhs.rsplit(op, 1)
                            x = int(x.split()[-1])
                            y = int(y.split()[0])
                            assert f(x, y) == rhs_num, frag


def test_code_examples_parse_as_python():
    import ast
    for ex in D.gen_code(random.Random(3), 100):
        ast.parse(ex)


def test_chat_examples_have_dialogue_structure():
    for ex in D.gen_chat(random.Random(4), 50):
        assert "User: " in ex and "Assistant: " in ex


def test_task_statistics_differ_as_designed():
    """code must be more n-gram-repetitive than chat (drives the paper's
    per-dataset contrast)."""
    rng = random.Random(0)
    code = "".join(D.gen_code(rng, 150))
    chat = "".join(D.gen_chat(rng, 150))

    def trigram_repeat_rate(text):
        words = text.split()
        tris = list(zip(words, words[1:], words[2:]))
        return 1.0 - len(set(tris)) / max(len(tris), 1)

    assert trigram_repeat_rate(code) > trigram_repeat_rate(chat) + 0.02


def test_build_corpora_writes_files(tmp_path):
    paths = D.build_corpora(str(tmp_path), seed=1, n_train=5, n_eval=2)
    assert set(paths) == {"chat", "code", "math"}
    for train, evalp in paths.values():
        assert len(open(train).read()) > 50
        assert len(open(evalp).read()) > 20
