"""N-gram table extraction + AOT lowering tests (build-path integration)."""

import os

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from compile import aot, model as M, ngram_tables as NG
from compile.configs import MODELS, step_shapes

jax.config.update("jax_platform_name", "cpu")

CFG = MODELS["small"]


@pytest.fixture(scope="module")
def params():
    return M.init_params(CFG, seed=3)


def test_bigram_topk_is_true_argmax(params):
    table = NG.bigram_topk(CFG, params, 8)
    assert table.shape == (CFG.vocab_size, 8)
    # spot-check a few rows against a direct forward pass
    for x in [0, 17, 255, CFG.vocab_size - 1]:
        logits = np.asarray(
            M.forward_train(CFG, params, jnp.asarray([[x]], jnp.int32))[0, 0])
        want = np.argsort(-logits)[:8]
        np.testing.assert_array_equal(table[x], want)


def test_unigram_is_permutation_prefix(params):
    u = NG.unigram_topk(CFG, params, 64)
    assert len(np.unique(u)) == 64
    assert u.max() < CFG.vocab_size


def test_extended_bigram_follows_top1_chains(params):
    bigram = NG.bigram_topk(CFG, params, 4)
    ext = NG.extended_bigram(bigram, 4, 5)
    assert ext.shape == (CFG.vocab_size, 4, 5)
    for x in [1, 100]:
        for j in range(4):
            assert ext[x, j, 0] == bigram[x, j]
            for d in range(1, 5):
                assert ext[x, j, d] == bigram[ext[x, j, d - 1], 0]


def test_table_binary_roundtrip(tmp_path, params):
    t = NG.bigram_topk(CFG, params, 4)
    p = str(tmp_path / "t.bin")
    NG.write_table(p, t)
    back = NG.read_table(p)
    np.testing.assert_array_equal(t, back)
    # 3d
    ext = NG.extended_bigram(t, 4, 3)
    NG.write_table(p, ext)
    np.testing.assert_array_equal(ext, NG.read_table(p))


def test_step_shapes_cover_paper_grid():
    shapes = set(step_shapes())
    assert (1, 0) in shapes  # greedy baseline
    assert (10, 10) in shapes  # the paper's default
    for k in [1, 5, 10, 20, 25]:
        for w in [2, 4, 6, 8, 10, 12, 14]:
            assert (k, w) in shapes, (k, w)


def test_lowered_step_hlo_has_expected_parameters(params):
    lowered = aot.lower_step(CFG, params, 2, 3)
    text = aot.to_hlo_text(lowered)
    assert "ENTRY" in text
    # params + tokens + kcache + vcache + len
    n_params = len(M.param_spec(CFG))
    for i in range(n_params + 4):
        assert f"parameter({i})" in text, f"missing parameter({i})"
    assert f"parameter({n_params + 4})" not in text
    # output tuple: (next_ids i32, k_tail f32, v_tail f32)
    assert "s32[2,4]" in text
    assert f"f32[{CFG.n_layers},2,4,{CFG.n_heads},{CFG.head_dim}]" in text


def test_lowered_prefill_hlo_shapes(params):
    lowered = aot.lower_prefill(CFG, params, 64)
    text = aot.to_hlo_text(lowered)
    assert "ENTRY" in text
    assert f"f32[{CFG.n_layers},{CFG.max_len},{CFG.n_heads},{CFG.head_dim}]" in text


def test_params_bin_is_flat_f32(tmp_path, params):
    p = str(tmp_path / "params.bin")
    aot.write_params_bin(p, CFG, params)
    data = np.fromfile(p, np.float32)
    assert data.size == CFG.n_params()
    # first tensor is tok_emb, row-major
    np.testing.assert_allclose(
        data[: CFG.vocab_size * CFG.d_model].reshape(CFG.vocab_size, CFG.d_model),
        np.asarray(params[0]),
    )


def test_build_stamp_changes_with_sources(monkeypatch):
    s1 = aot.build_stamp()
    assert len(s1) == 16
    # stamp is stable across calls
    assert aot.build_stamp() == s1
