"""L2 correctness: the speculative verify step must agree exactly (argmax
level) with the dense training-time forward — the invariant the whole
guess-and-verify scheme rests on — plus shape/prefill coverage."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from compile import model as M
from compile.configs import MODELS, ModelConfig

jax.config.update("jax_platform_name", "cpu")

CFG = MODELS["small"]


@pytest.fixture(scope="module")
def params():
    return M.init_params(CFG, seed=11)


def dense_next_tokens(params, seq):
    logits = M.forward_train(CFG, params, seq[None, :])
    return np.asarray(jnp.argmax(logits, -1)[0])


@settings(max_examples=8, deadline=None)
@given(
    plen=st.integers(4, 40),
    k=st.integers(1, 4),
    w=st.integers(0, 6),
    seed=st.integers(0, 2**16),
)
def test_spec_step_matches_dense_forward(params, plen, k, w, seed):
    rng = np.random.default_rng(seed)
    total = plen + w + 1
    seq = jnp.asarray(rng.integers(0, CFG.vocab_size, size=total), jnp.int32)
    dense_next = dense_next_tokens(params, seq)

    P = 64
    toks = jnp.concatenate([seq[:plen], jnp.zeros(P - plen, jnp.int32)])[None, :]
    nid, kc, vc = M.forward_prefill(CFG, params, toks, jnp.int32(plen))
    assert int(nid) == int(dense_next[plen - 1])

    # verify the true continuation in row 0 (k rows, others random drafts)
    block_rows = [seq[plen:plen + w + 1]]
    for _ in range(k - 1):
        block_rows.append(jnp.asarray(
            rng.integers(0, CFG.vocab_size, size=w + 1), jnp.int32))
    block = jnp.stack(block_rows)
    block = block.at[:, 0].set(seq[plen])  # anchor column
    ni, ktail, vtail = M.forward_spec_step(CFG, params, block, kc, vc, jnp.int32(plen))
    # row 0 fed the true continuation, so outputs must equal dense argmax
    np.testing.assert_array_equal(
        np.asarray(ni[0]), dense_next[plen:plen + w + 1])
    assert ktail.shape == (CFG.n_layers, k, w + 1, CFG.n_heads, CFG.head_dim)
    assert vtail.shape == ktail.shape


def test_pallas_and_jnp_paths_agree(params):
    rng = np.random.default_rng(5)
    seq = jnp.asarray(rng.integers(0, CFG.vocab_size, size=30), jnp.int32)
    P = 64
    toks = jnp.concatenate([seq[:20], jnp.zeros(P - 20, jnp.int32)])[None, :]
    _, kc, vc = M.forward_prefill(CFG, params, toks, jnp.int32(20))
    block = jnp.stack([seq[20:26]] * 3)
    a = M.forward_spec_step(CFG, params, block, kc, vc, jnp.int32(20), use_pallas=True)
    b = M.forward_spec_step(CFG, params, block, kc, vc, jnp.int32(20), use_pallas=False)
    np.testing.assert_array_equal(np.asarray(a[0]), np.asarray(b[0]))
    np.testing.assert_allclose(np.asarray(a[1]), np.asarray(b[1]), rtol=2e-4, atol=2e-4)


def test_kv_cache_commit_then_continue(params):
    """Simulate the rust engine's commit: write the tail into the cache and
    keep decoding — must keep matching the dense forward."""
    rng = np.random.default_rng(9)
    seq = jnp.asarray(rng.integers(0, CFG.vocab_size, size=40), jnp.int32)
    dense_next = dense_next_tokens(params, seq)
    plen, w1 = 10, 4
    P = 64
    toks = jnp.concatenate([seq[:plen], jnp.zeros(P - plen, jnp.int32)])[None, :]
    _, kc, vc = M.forward_prefill(CFG, params, toks, jnp.int32(plen))
    kc, vc = np.array(kc), np.array(vc)  # writable copies
    pos = plen
    for _ in range(4):
        block = seq[pos:pos + w1][None, :]
        ni, ktail, vtail = M.forward_spec_step(
            CFG, params, block, jnp.asarray(kc), jnp.asarray(vc), jnp.int32(pos))
        np.testing.assert_array_equal(np.asarray(ni[0]), dense_next[pos:pos + w1])
        kc[:, pos:pos + w1] = np.asarray(ktail)[:, 0]
        vc[:, pos:pos + w1] = np.asarray(vtail)[:, 0]
        pos += w1


@pytest.mark.parametrize("name", list(MODELS))
def test_param_spec_matches_init(name):
    cfg = MODELS[name]
    params = M.init_params(cfg)
    spec = M.param_spec(cfg)
    assert len(params) == len(spec)
    for p, (n, shape) in zip(params, spec):
        assert tuple(p.shape) == shape, n
    total = sum(int(np.prod(s)) for _, s in spec)
    assert total == cfg.n_params()


def test_prefill_length_masking(params):
    """Padding tokens beyond `length` must not affect the next-token id."""
    rng = np.random.default_rng(2)
    seq = jnp.asarray(rng.integers(0, CFG.vocab_size, size=12), jnp.int32)
    P = 64
    a = jnp.concatenate([seq, jnp.zeros(P - 12, jnp.int32)])[None, :]
    b = jnp.concatenate([seq, jnp.asarray(
        rng.integers(0, CFG.vocab_size, size=P - 12), jnp.int32)])[None, :]
    na, _, _ = M.forward_prefill(CFG, params, a, jnp.int32(12))
    nb, _, _ = M.forward_prefill(CFG, params, b, jnp.int32(12))
    assert int(na) == int(nb)


def test_rope_positions_differ():
    """Sanity: the same token at different positions attends differently."""
    cfg = ModelConfig(name="t", vocab_size=64, d_model=32, n_layers=1,
                      n_heads=2, head_dim=16, max_len=64)
    params = M.init_params(cfg, seed=0)
    seq = jnp.asarray([5] * 10, jnp.int32)
    logits = M.forward_train(cfg, params, seq[None, :])
    # position 0 and position 9 logits must differ (RoPE + causal window)
    assert not np.allclose(np.asarray(logits[0, 0]), np.asarray(logits[0, 9]))
