"""Emit cross-language tokenizer fixtures: python encodings of a diverse
string set, consumed by rust/tests/tokenizer_parity.rs. Cheap — runs on
every `make artifacts` without invalidating the training stamp."""

import json
import os
import sys

from .tokenizer import BpeTokenizer

CASES = [
    "hello world",
    "Question: Tom has 12 apples. He buys 7 more.",
    "def scale(x, y):\n    return x + y\n",
    "User: What is the capital of Kalorane?\nAssistant: The capital is Venmi.",
    "   leading and trailing   ",
    "tabs\tnewlines\n\nmixed  runs",
    "numbers 12345 and 67 * 89 = ?",
    "unicode: héllo ☃ 你好",
    "",
    " ",
    "a",
    "The answer is 19.",
]


def main():
    out_dir = sys.argv[1] if len(sys.argv) > 1 else os.path.join(
        os.path.dirname(os.path.abspath(__file__)), "..", "..", "artifacts")
    tok_path = os.path.join(out_dir, "tokenizer.json")
    tok = BpeTokenizer.from_json(open(tok_path).read())
    cases = []
    for text in CASES:
        ids = tok.encode(text)
        assert tok.decode(ids) == text, f"python round-trip failed: {text!r}"
        cases.append({"text": text, "ids": ids})
    with open(os.path.join(out_dir, "tokenizer_fixtures.json"), "w") as fh:
        json.dump({"cases": cases}, fh)
    print(f"[fixtures] wrote {len(cases)} tokenizer fixtures")


if __name__ == "__main__":
    main()
