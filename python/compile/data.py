"""Synthetic task corpora standing in for the paper's benchmarks.

Three generators with the *statistical* roles of the paper's datasets
(DESIGN.md §Substitutions):

  chat  ~ MT-Bench   : multi-turn QA, many unique tokens (generated entity
                       names), lower n-gram repetition.
  code  ~ HumanEval  : python-like functions, heavy idiom repetition, long
                       literally-repeated spans -> long context-n-gram drafts.
  math  ~ GSM8K      : templated word problems whose solutions restate
                       numbers from the problem; arithmetic spans of varied
                       width -> wide acceptance-length distribution.

The generated files under ``artifacts/data/`` are the ground truth consumed
by BOTH the python training loop and the rust bench harness.
"""

import random


# --------------------------------------------------------------------------
# small deterministic vocabulary pools
SUBJECTS = ["Tom", "Mia", "Sam", "Ana", "Leo", "Zoe", "Max", "Ivy", "Ben", "Eva"]
OBJECTS = ["apples", "books", "coins", "cards", "pens", "stamps", "shells", "marbles"]
VERBS = ["buys", "finds", "sells", "loses", "gives away", "wins"]
TOPICS = ["rivers", "planets", "metals", "birds", "engines", "glaciers",
          "violins", "mushrooms", "comets", "harbors", "bridges", "orchards"]
ADJS = ["large", "small", "ancient", "modern", "bright", "quiet", "rapid", "dense"]
FUNC_NAMES = ["scale", "shift", "clamp", "mix", "fold", "rank", "merge_vals", "norm"]
VAR_NAMES = ["x", "y", "z", "a", "b", "n", "m", "v"]


def _entity(rng: random.Random) -> str:
    # synthetic proper nouns -> many unique tokens, like MT-Bench
    syll = ["ka", "lo", "mi", "ra", "ven", "tor", "bel", "nis", "qua", "zem",
            "fi", "dor", "ul", "pra", "sky"]
    return "".join(rng.choice(syll) for _ in range(rng.randint(2, 3))).capitalize()


def gen_chat(rng: random.Random, n_examples: int) -> list:
    """Multi-turn QA with unique entities. Answer restates the question."""
    examples = []
    for _ in range(n_examples):
        turns = []
        for _t in range(rng.randint(1, 3)):
            kind = rng.randrange(4)
            if kind == 0:
                place, city = _entity(rng), _entity(rng)
                q = f"What is the capital of {place}?"
                a = f"The capital of {place} is {city}."
            elif kind == 1:
                topic = rng.choice(TOPICS)
                adj = rng.choice(ADJS)
                q = f"Tell me about {adj} {topic}."
                a = (f"Most {adj} {topic} are studied for their structure. "
                     f"A notable property of {adj} {topics_sg(topic)} systems is stability.")
            elif kind == 2:
                name = _entity(rng)
                topic = rng.choice(TOPICS)
                q = f"Who first described the {topic} of {name}?"
                a = f"The {topic} of {name} were first described by {_entity(rng)} of {_entity(rng)}."
            else:
                a1, a2 = rng.choice(ADJS), rng.choice(ADJS)
                t1 = rng.choice(TOPICS)
                q = f"Compare {a1} and {a2} {t1}."
                a = (f"Compared to {a2} {t1}, {a1} {t1} tend to change more slowly, "
                     f"although both kinds of {t1} share a common origin.")
            turns.append(f"User: {q}\nAssistant: {a}")
        examples.append("\n".join(turns) + "\n\n")
    return examples


def topics_sg(t: str) -> str:
    return t[:-1] if t.endswith("s") else t


def gen_code(rng: random.Random, n_examples: int) -> list:
    """Python-like functions built from a small set of idioms."""
    examples = []
    for _ in range(n_examples):
        f = rng.choice(FUNC_NAMES)
        v1, v2 = rng.sample(VAR_NAMES, 2)
        kind = rng.randrange(5)
        if kind == 0:
            c = rng.randint(2, 9)
            body = (f"def {f}({v1}, {v2}):\n"
                    f"    result = []\n"
                    f"    for i in range(len({v1})):\n"
                    f"        result.append({v1}[i] * {c} + {v2}[i])\n"
                    f"    return result\n")
        elif kind == 1:
            body = (f"def {f}({v1}):\n"
                    f"    if {v1} is None:\n"
                    f"        return None\n"
                    f"    total = 0\n"
                    f"    for item in {v1}:\n"
                    f"        total = total + item\n"
                    f"    return total\n")
        elif kind == 2:
            c = rng.randint(2, 9)
            body = (f"def {f}({v1}, {v2}={c}):\n"
                    f"    out = {{}}\n"
                    f"    for key in {v1}:\n"
                    f"        out[key] = {v1}[key] + {v2}\n"
                    f"    return out\n")
        elif kind == 3:
            body = (f"def {f}({v1}):\n"
                    f"    low = 0\n"
                    f"    high = len({v1}) - 1\n"
                    f"    while low < high:\n"
                    f"        mid = (low + high) // 2\n"
                    f"        if {v1}[mid] < 0:\n"
                    f"            low = mid + 1\n"
                    f"        else:\n"
                    f"            high = mid\n"
                    f"    return low\n")
        else:
            c = rng.randint(2, 9)
            body = (f"def {f}({v1}, {v2}):\n"
                    f"    assert len({v1}) == len({v2})\n"
                    f"    return [pair[0] - pair[1] for pair in zip({v1}, {v2})]\n"
                    f"\n"
                    f"def {f}_{c}({v1}):\n"
                    f"    return {f}({v1}, {v1}[:{c}])\n")
        examples.append(body + "\n")
    return examples


def gen_math(rng: random.Random, n_examples: int) -> list:
    """GSM8K-style word problems; solutions restate problem numbers."""
    examples = []
    for _ in range(n_examples):
        s = rng.choice(SUBJECTS)
        o = rng.choice(OBJECTS)
        kind = rng.randrange(3)
        if kind == 0:
            a, b = rng.randint(3, 80), rng.randint(2, 60)
            q = f"{s} has {a} {o}. {s} {rng.choice(VERBS[:2])} {b} more. How many {o} does {s} have now?"
            sol = f"{s} starts with {a} {o}. After getting {b} more, {s} has {a} + {b} = {a + b} {o}. The answer is {a + b}."
        elif kind == 1:
            a, b = rng.randint(20, 99), rng.randint(2, 19)
            q = f"{s} has {a} {o} and gives {b} to a friend. How many {o} are left?"
            sol = f"{s} gives away {b} of the {a} {o}, leaving {a} - {b} = {a - b} {o}. The answer is {a - b}."
        else:
            a, b = rng.randint(2, 12), rng.randint(3, 12)
            q = f"Each box holds {a} {o}. {s} has {b} boxes. How many {o} in total?"
            sol = f"There are {b} boxes with {a} {o} each, so {b} * {a} = {a * b} {o}. The answer is {a * b}."
        examples.append(f"Question: {q}\nAnswer: {sol}\n\n")
    return examples


GENERATORS = {"chat": gen_chat, "code": gen_code, "math": gen_math}


def build_corpora(out_dir: str, seed: int = 7, n_train: int = 1200, n_eval: int = 64):
    """Write {task}_{train,eval}.txt under out_dir. Returns dict of paths."""
    import os

    os.makedirs(out_dir, exist_ok=True)
    paths = {}
    for task, gen in GENERATORS.items():
        rng = random.Random(seed * 1000 + hash(task) % 1000)
        train = gen(rng, n_train)
        evale = gen(rng, n_eval)
        ptrain = os.path.join(out_dir, f"{task}_train.txt")
        peval = os.path.join(out_dir, f"{task}_eval.txt")
        with open(ptrain, "w") as fh:
            fh.write("".join(train))
        with open(peval, "w") as fh:
            fh.write("".join(evale))
        paths[task] = (ptrain, peval)
    return paths
