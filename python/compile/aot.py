"""AOT build orchestrator: the ONLY python that runs, and it runs once.

    python -m compile.aot --out-dir ../artifacts

Pipeline (everything cached by a build-stamp; re-runs are no-ops):
  1. generate the synthetic corpora  (data.py)           -> data/*.txt
  2. train the byte-BPE tokenizer    (tokenizer.py)      -> tokenizer.json
  3. train the three nano models     (train.py)          -> models/*/train_log.json
  4. extract N-gram tables           (ngram_tables.py)   -> models/*/{bigram,unigram,ext_bigram}.bin
  5. dump flat f32 weights                               -> models/*/params.bin
  6. lower prefill + the (k, w) verify-step grid to HLO TEXT (not
     .serialize(): the rust side's xla_extension 0.5.1 rejects jax>=0.5
     64-bit-id protos; the text parser reassigns ids)    -> models/*/*.hlo.txt
  7. write manifest.json — the rust runtime's single entry point.
"""

import argparse
import functools
import hashlib
import json
import os
import time

import jax
import jax.numpy as jnp
import numpy as np
from jax._src.lib import xla_client as xc

from . import data as D
from . import model as M
from . import ngram_tables as NG
from . import train as T
from .configs import (BIGRAM_TOPK, EXT_BIGRAM_W, MODELS, PREFILL_BUCKETS,
                      UNIGRAM_TOPK, manifest_model_entry, step_shapes)
from .tokenizer import BpeTokenizer, train_bpe

VOCAB_SIZE = 512


def to_hlo_text(lowered) -> str:
    """HLO text via stablehlo -> XlaComputation (see /opt/xla-example)."""
    mlir_mod = lowered.compiler_ir("stablehlo")
    comp = xc._xla.mlir.mlir_module_to_xla_computation(
        str(mlir_mod), use_tuple_args=False, return_tuple=True)
    return comp.as_hlo_text()


def _hash_files(names) -> str:
    root = os.path.dirname(os.path.abspath(__file__))
    h = hashlib.sha256()
    for name in names:
        with open(os.path.join(root, name), "rb") as fh:
            h.update(fh.read())
    return h.hexdigest()[:16]


# Two-stage caching: training artifacts (params, tables, corpora) and
# lowered HLO have independent stamps, so editing only the lowering path
# (e.g. a perf-pass change in aot.py) re-lowers WITHOUT retraining.
TRAIN_SOURCES = ["configs.py", "data.py", "tokenizer.py", "train.py",
                 "model.py", "kernels/attention.py", "kernels/ref.py",
                 "ngram_tables.py"]
LOWER_SOURCES = ["configs.py", "model.py", "kernels/attention.py", "aot.py"]


def train_stamp(steps: int) -> str:
    return _hash_files(TRAIN_SOURCES) + f"-steps{steps}"


def lower_stamp() -> str:
    return _hash_files(LOWER_SOURCES) + "-attn" + os.environ.get("NGRAM_AOT_ATTN", "auto")


def build_stamp() -> str:
    """Hash of every compile-path source file — the artifact cache key."""
    root = os.path.dirname(os.path.abspath(__file__))
    h = hashlib.sha256()
    for dirpath, _, files in sorted(os.walk(root)):
        for f in sorted(files):
            # fixtures.py only emits test fixtures; it never affects the
            # trained artifacts, so it must not invalidate the cache.
            if f.endswith(".py") and f != "fixtures.py":
                with open(os.path.join(dirpath, f), "rb") as fh:
                    h.update(fh.read())
    return h.hexdigest()[:16]


def load_params_bin(path, cfg):
    """Inverse of write_params_bin: flat f32 LE -> param list."""
    data = np.fromfile(path, np.float32)
    params, off = [], 0
    for _, shape in M.param_spec(cfg):
        n = int(np.prod(shape))
        params.append(jnp.asarray(data[off:off + n].reshape(shape)))
        off += n
    assert off == data.size, (off, data.size)
    return params


# Shape-dependent attention dispatch (perf pass, EXPERIMENTS.md §Perf-L2):
# the interpret-mode Pallas kernel lowers to a tile loop whose fixed
# overhead dominates small blocks on CPU (k·w1 rows <= ~150), while its
# VMEM-tiled schedule wins for large blocks where dense jnp materializes
# (k·w1, max_len) score tensors. Measured crossover on this host: (10,10)
# 8.8 -> 8.1 ms in favor of jnp, (25,14) 20.0 -> 27.0 ms in favor of
# Pallas. Override with NGRAM_AOT_ATTN={pallas,jnp,auto}.
PALLAS_MIN_ROWS = 200


def step_uses_pallas(k, w):
    mode = os.environ.get("NGRAM_AOT_ATTN", "auto")
    if mode == "pallas":
        return True
    if mode == "jnp":
        return False
    return k * (w + 1) >= PALLAS_MIN_ROWS


def lower_step(cfg, params, k, w):
    w1 = w + 1
    shapes = (
        jax.ShapeDtypeStruct((k, w1), jnp.int32),
        jax.ShapeDtypeStruct((cfg.n_layers, cfg.max_len, cfg.n_heads,
                              cfg.head_dim), jnp.float32),
        jax.ShapeDtypeStruct((cfg.n_layers, cfg.max_len, cfg.n_heads,
                              cfg.head_dim), jnp.float32),
        jax.ShapeDtypeStruct((), jnp.int32),
    )
    pshapes = [jax.ShapeDtypeStruct(p.shape, p.dtype) for p in params]
    fn = functools.partial(M.forward_spec_step, cfg,
                           use_pallas=step_uses_pallas(k, w))
    return jax.jit(fn).lower(pshapes, *shapes)


def lower_commit(cfg, k, w):
    """Device-side KV commit for one (k, w) shape (perf path)."""
    w1 = w + 1
    cache = jax.ShapeDtypeStruct(
        (cfg.n_layers, cfg.max_len, cfg.n_heads, cfg.head_dim), jnp.float32)
    tail = jax.ShapeDtypeStruct(
        (cfg.n_layers, k, w1, cfg.n_heads, cfg.head_dim), jnp.float32)
    scalar = jax.ShapeDtypeStruct((), jnp.int32)
    fn = functools.partial(M.kv_commit, cfg)
    return jax.jit(fn).lower(cache, cache, tail, tail, scalar, scalar)


def lower_prefill(cfg, params, p_bucket):
    shapes = (
        jax.ShapeDtypeStruct((1, p_bucket), jnp.int32),
        jax.ShapeDtypeStruct((), jnp.int32),
    )
    pshapes = [jax.ShapeDtypeStruct(p.shape, p.dtype) for p in params]
    fn = functools.partial(M.forward_prefill, cfg)
    return jax.jit(fn).lower(pshapes, *shapes)


def write_params_bin(path, cfg, params):
    """Flat little-endian f32 blob in param_spec order."""
    with open(path, "wb") as fh:
        for arr in params:
            fh.write(np.ascontiguousarray(np.asarray(arr), np.float32).tobytes())


def build_model(name, cfg, token_ids, out_dir, steps, force):
    mdir = os.path.join(out_dir, "models", name)
    os.makedirs(mdir, exist_ok=True)
    t0 = time.time()

    # --- stage 1: train + tables (skipped when train sources unchanged)
    tstamp_path = os.path.join(mdir, "train_stamp.txt")
    tstamp = train_stamp(steps)
    params_path = os.path.join(mdir, "params.bin")
    log_path = os.path.join(mdir, "train_log.json")
    if (not force and os.path.exists(params_path) and os.path.exists(tstamp_path)
            and open(tstamp_path).read() == tstamp):
        print(f"[aot] {name}: training cached (stamp match)", flush=True)
        params = load_params_bin(params_path, cfg)
        log = json.load(open(log_path))
    else:
        print(f"[aot] training {name} ({cfg.n_params():,} params, "
              f"{steps} steps)...", flush=True)
        params, log = T.train(cfg, token_ids, steps=steps, seed=42,
                              log_path=log_path)
        print(f"[aot] {name}: n-gram tables", flush=True)
        bigram = NG.bigram_topk(cfg, params, BIGRAM_TOPK)
        NG.write_table(os.path.join(mdir, "bigram.bin"), bigram)
        NG.write_table(os.path.join(mdir, "unigram.bin"),
                       NG.unigram_topk(cfg, params, UNIGRAM_TOPK)[None, :])
        NG.write_table(os.path.join(mdir, "ext_bigram.bin"),
                       NG.extended_bigram(bigram, BIGRAM_TOPK, EXT_BIGRAM_W))
        write_params_bin(params_path, cfg, params)
        with open(tstamp_path, "w") as fh:
            fh.write(tstamp)

    # --- stage 2: lowering (skipped when lowering sources unchanged)
    lstamp_path = os.path.join(mdir, "lower_stamp.txt")
    lstamp = lower_stamp()
    step_files = {f"{k},{w}": f"step_k{k}_w{w}.hlo.txt" for (k, w) in step_shapes()}
    prefill_files = {str(p): f"prefill_p{p}.hlo.txt" for p in PREFILL_BUCKETS}
    commit_files = {f"{k},{w}": f"commit_k{k}_w{w}.hlo.txt" for (k, w) in step_shapes()}
    all_files = list(step_files.values()) + list(prefill_files.values()) \
        + list(commit_files.values())
    cached = (not force and os.path.exists(lstamp_path)
              and open(lstamp_path).read() == lstamp
              and all(os.path.exists(os.path.join(mdir, f)) for f in all_files))
    if cached:
        print(f"[aot] {name}: lowering cached (stamp match)", flush=True)
    else:
        for (k, w) in step_shapes():
            with open(os.path.join(mdir, step_files[f"{k},{w}"]), "w") as fh:
                fh.write(to_hlo_text(lower_step(cfg, params, k, w)))
            with open(os.path.join(mdir, commit_files[f"{k},{w}"]), "w") as fh:
                fh.write(to_hlo_text(lower_commit(cfg, k, w)))
        print(f"[aot] {name}: {len(step_files)} step + commit HLOs lowered "
              f"({time.time() - t0:.0f}s)", flush=True)
        for p in PREFILL_BUCKETS:
            with open(os.path.join(mdir, prefill_files[str(p)]), "w") as fh:
                fh.write(to_hlo_text(lower_prefill(cfg, params, p)))
        with open(lstamp_path, "w") as fh:
            fh.write(lstamp)

    entry = manifest_model_entry(cfg)
    entry.update({
        "dir": f"models/{name}",
        "params_bin": "params.bin",
        "param_spec": [{"name": n, "shape": list(s)}
                       for n, s in M.param_spec(cfg)],
        "steps": step_files,
        "prefills": prefill_files,
        "commits": commit_files,
        "tables": {"bigram": "bigram.bin", "unigram": "unigram.bin",
                   "ext_bigram": "ext_bigram.bin"},
        "train_final_loss": log["final_loss"],
    })
    return entry


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--out-dir", default=os.path.join(
        os.path.dirname(os.path.abspath(__file__)), "..", "..", "artifacts"))
    ap.add_argument("--steps", type=int,
                    default=int(os.environ.get("NGRAM_TRAIN_STEPS", "240")))
    ap.add_argument("--models", default="small,base,large")
    ap.add_argument("--force", action="store_true")
    args = ap.parse_args()
    out_dir = os.path.abspath(args.out_dir)
    os.makedirs(out_dir, exist_ok=True)

    stamp = build_stamp() + f"-steps{args.steps}-{args.models}"
    stamp_path = os.path.join(out_dir, "build_stamp.txt")
    manifest_path = os.path.join(out_dir, "manifest.json")
    if (not args.force and os.path.exists(stamp_path)
            and os.path.exists(manifest_path)
            and open(stamp_path).read() == stamp):
        print("[aot] artifacts up to date (stamp match); nothing to do")
        return

    t0 = time.time()
    print("[aot] generating corpora", flush=True)
    data_dir = os.path.join(out_dir, "data")
    paths = D.build_corpora(data_dir, seed=7)

    print("[aot] training tokenizer", flush=True)
    train_text = "".join(open(p[0]).read() for p in paths.values())
    tok = train_bpe(train_text, VOCAB_SIZE)
    with open(os.path.join(out_dir, "tokenizer.json"), "w") as fh:
        fh.write(tok.to_json())
    print(f"[aot] tokenizer vocab={tok.vocab_size}", flush=True)

    token_ids = np.asarray(tok.encode(train_text), np.int32)
    print(f"[aot] corpus: {len(train_text):,} chars -> "
          f"{len(token_ids):,} tokens", flush=True)

    manifest = {
        "version": 1,
        "stamp": stamp,
        "vocab_size": tok.vocab_size,
        "tokenizer": "tokenizer.json",
        "data": {t: {"train": os.path.relpath(p[0], out_dir),
                     "eval": os.path.relpath(p[1], out_dir)}
                 for t, p in paths.items()},
        "step_grid": [[k, w] for (k, w) in step_shapes()],
        "prefill_buckets": PREFILL_BUCKETS,
        "table_topk": {"bigram": BIGRAM_TOPK, "unigram": UNIGRAM_TOPK,
                       "ext_bigram_w": EXT_BIGRAM_W},
        "models": {},
    }
    for name in args.models.split(","):
        cfg = MODELS[name]
        # model vocab may exceed the tokenizer's (BPE can stop early);
        # unused logit rows are simply never produced by greedy argmax.
        assert cfg.vocab_size >= tok.vocab_size, \
            f"config vocab {cfg.vocab_size} < tokenizer {tok.vocab_size}"
        manifest["models"][name] = build_model(
            name, cfg, token_ids, out_dir, args.steps, args.force)

    with open(manifest_path, "w") as fh:
        json.dump(manifest, fh, indent=1)
    with open(stamp_path, "w") as fh:
        fh.write(stamp)
    print(f"[aot] DONE in {time.time() - t0:.0f}s -> {manifest_path}")


if __name__ == "__main__":
    main()
