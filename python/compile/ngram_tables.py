"""Model-derived N-gram tables (paper §4.1, Appendix B.1).

Three learning-free artifacts extracted from the trained model:

  unigram   top-k token list from the embedding geometry: d(x) = distance of
            the output embedding u_x from the mean, under the inner product
            induced by the input-embedding covariance (App. B.1).
  bigram    top-k next tokens of p_M(. | x) for every x — one batched
            forward pass over the whole vocabulary ("<= 1 minute for
            Mistral-7B on an A100"; milliseconds here).
  extended bigram  greedy bigram chains: entry (x, j) holds the w-step
            future obtained by starting at the j-th top-k continuation of x
            and following the bigram's top-1 repeatedly (§4.1 Extensions).

Binary format (consumed by rust/src/draft/tables.rs): little-endian u32,
header [magic, rows, cols, depth] then row-major data.
"""

import struct

import jax.numpy as jnp
import numpy as np

from . import model as M
from .configs import ModelConfig

MAGIC = 0x4E47524D  # "NGRM"


def write_table(path: str, arr: np.ndarray):
    """arr: u32 array of rank 2 (rows, cols) or 3 (rows, cols, depth)."""
    a = np.ascontiguousarray(arr.astype(np.uint32))
    rows, cols = a.shape[0], a.shape[1]
    depth = a.shape[2] if a.ndim == 3 else 1
    with open(path, "wb") as fh:
        fh.write(struct.pack("<4I", MAGIC, rows, cols, depth))
        fh.write(a.tobytes())


def read_table(path: str) -> np.ndarray:
    with open(path, "rb") as fh:
        magic, rows, cols, depth = struct.unpack("<4I", fh.read(16))
        assert magic == MAGIC
        data = np.frombuffer(fh.read(), dtype=np.uint32)
    a = data.reshape(rows, cols, depth)
    return a[..., 0] if depth == 1 else a


def unigram_topk(cfg: ModelConfig, params, k: int) -> np.ndarray:
    """Paper App. B.1: rank tokens by distance of their output embedding
    from the mean, under the input-embedding covariance inner product."""
    spec = [n for n, _ in M.param_spec(cfg)]
    wenc = np.asarray(params[spec.index("tok_emb")])          # (V, d)
    wdec = np.asarray(params[spec.index("lm_head")]).T        # (V, d)
    cov = wenc.T @ wenc / wenc.shape[0]                       # (d, d)
    mu = wdec.mean(axis=0, keepdims=True)                     # (1, d)
    diff = wdec - mu
    # squared distance ||u_x - mu||_V^2 = (u_x - mu) cov (u_x - mu)^T
    d2 = np.einsum("vd,de,ve->v", diff, cov, diff)
    order = np.argsort(d2)                                    # closest first
    return order[:k].astype(np.uint32)


def bigram_topk(cfg: ModelConfig, params, k: int, chunk: int = 128) -> np.ndarray:
    """(V, k) top-k of p_M(. | x) for every token x: one fwd pass per chunk."""
    V = cfg.vocab_size
    outs = []
    for s in range(0, V, chunk):
        toks = jnp.arange(s, min(s + chunk, V), dtype=jnp.int32)[:, None]
        logits = M.forward_train(cfg, params, toks)[:, 0, :]  # (chunk, V)
        _, idx = top_k_np(np.asarray(logits), k)
        outs.append(idx)
    return np.concatenate(outs).astype(np.uint32)


def top_k_np(logits: np.ndarray, k: int):
    idx = np.argpartition(-logits, k, axis=-1)[..., :k]
    vals = np.take_along_axis(logits, idx, axis=-1)
    order = np.argsort(-vals, axis=-1)
    return vals, np.take_along_axis(idx, order, axis=-1)


def extended_bigram(bigram: np.ndarray, k: int, w: int) -> np.ndarray:
    """(V, k, w): start at bigram[x][j], then follow bigram top-1 chains."""
    V = bigram.shape[0]
    top1 = bigram[:, 0].astype(np.uint32)                     # (V,)
    out = np.zeros((V, k, w), dtype=np.uint32)
    cur = bigram[:, :k].astype(np.uint32)                     # (V, k)
    out[:, :, 0] = cur
    for step in range(1, w):
        cur = top1[cur]
        out[:, :, step] = cur
    return out

