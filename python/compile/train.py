"""Build-time nano-training: AdamW + cosine schedule, hand-rolled (no optax).

Trains each paper-analog model on the mixed synthetic corpus so that greedy
decoding is in-distribution and the N-gram speculation statistics are
meaningful. Runs once inside `make artifacts`; the loss curve is written to
``artifacts/models/<name>/train_log.json`` and summarized in EXPERIMENTS.md.
"""

import functools
import json
import time

import jax
import jax.numpy as jnp
import numpy as np

from . import model as M
from .configs import ModelConfig


def adamw_init(params):
    return ([jnp.zeros_like(p) for p in params],
            [jnp.zeros_like(p) for p in params])


@functools.partial(jax.jit, static_argnums=(0,), donate_argnums=(1, 2, 3))
def _train_step(cfg: ModelConfig, params, mu, nu, tokens, step, lr_base,
                total_steps):
    loss, grads = jax.value_and_grad(
        lambda p: M.loss_fn(cfg, p, tokens))(params)
    b1, b2, eps, wd = 0.9, 0.95, 1e-8, 0.01
    warmup = 20.0
    t = step.astype(jnp.float32) + 1.0
    lr = lr_base * jnp.minimum(t / warmup, 1.0) * \
        0.5 * (1.0 + jnp.cos(jnp.pi * jnp.minimum(t / total_steps, 1.0)))
    bc1 = 1.0 - b1 ** t
    bc2 = 1.0 - b2 ** t
    new_params, new_mu, new_nu = [], [], []
    for p, g, m, v in zip(params, grads, mu, nu):
        m = b1 * m + (1 - b1) * g
        v = b2 * v + (1 - b2) * g * g
        upd = (m / bc1) / (jnp.sqrt(v / bc2) + eps) + wd * p
        new_params.append(p - lr * upd)
        new_mu.append(m)
        new_nu.append(v)
    return new_params, new_mu, new_nu, loss


def make_batches(token_ids: np.ndarray, batch: int, seq: int, steps: int,
                 seed: int = 0):
    """Random contiguous windows from the tokenized corpus."""
    rng = np.random.default_rng(seed)
    n = len(token_ids) - seq - 1
    assert n > 0, "corpus too small for sequence length"
    for _ in range(steps):
        starts = rng.integers(0, n, size=batch)
        yield np.stack([token_ids[s:s + seq + 1] for s in starts])


def train(cfg: ModelConfig, token_ids: np.ndarray, *, steps: int,
          batch: int = 8, seq: int = 128, lr: float = 3e-3, seed: int = 0,
          log_every: int = 20, log_path: str = None):
    params = M.init_params(cfg, seed=seed)
    mu, nu = adamw_init(params)
    log = {"model": cfg.name, "steps": steps, "batch": batch, "seq": seq,
           "lr": lr, "n_params": cfg.n_params(), "losses": []}
    t0 = time.time()
    for i, b in enumerate(make_batches(token_ids, batch, seq, steps, seed)):
        tokens = jnp.asarray(b, jnp.int32)
        params, mu, nu, loss = _train_step(
            cfg, params, mu, nu, tokens, jnp.int32(i), lr, float(steps))
        if i % log_every == 0 or i == steps - 1:
            lv = float(loss)
            log["losses"].append({"step": i, "loss": round(lv, 4),
                                  "elapsed_s": round(time.time() - t0, 1)})
            print(f"  [{cfg.name}] step {i:4d}  loss {lv:.4f}  "
                  f"({time.time() - t0:.0f}s)", flush=True)
    log["final_loss"] = log["losses"][-1]["loss"]
    log["wall_s"] = round(time.time() - t0, 1)
    if log_path:
        with open(log_path, "w") as fh:
            json.dump(log, fh, indent=1)
    return params, log
