"""Byte-level BPE tokenizer: trained once at artifact-build time.

The trained merge list is serialized to ``artifacts/tokenizer.json`` and
re-implemented in rust (``rust/src/tokenizer``); both sides are round-trip
tested against each other through the shared JSON artifact.

Vocabulary layout:
    0..255    raw bytes
    256..V-1  merge products, in merge order (id = 256 + merge_index)

Text is first split into *pieces* (GPT-2 style: a word keeps its single
leading space; whitespace runs are their own pieces); merges never cross
piece boundaries. The identical splitting rule is implemented in
``rust/src/tokenizer/mod.rs`` — keep the two in sync.
"""

import json
from collections import Counter


def split_pieces(data: bytes):
    """Split into pieces: ``(optional single leading space) + non-ws run``,
    with leftover whitespace runs as their own pieces."""
    pieces = []
    n = len(data)
    i = 0
    while i < n:
        c = data[i]
        if c == 0x20 and i + 1 < n and not _is_ws(data[i + 1]):
            # single space glued onto the following word
            j = i + 1
            while j < n and not _is_ws(data[j]):
                j += 1
            pieces.append(data[i:j])
            i = j
        elif _is_ws(c):
            j = i
            while j < n and _is_ws(data[j]):
                j += 1
            # if the run ends in a single space followed by a word, leave
            # that space for the word piece
            if j < n and data[j - 1] == 0x20:
                if j - 1 > i:
                    pieces.append(data[i:j - 1])
                i = j - 1
            else:
                pieces.append(data[i:j])
                i = j
        else:
            j = i
            while j < n and not _is_ws(data[j]):
                j += 1
            pieces.append(data[i:j])
            i = j
    return pieces


def _is_ws(b: int) -> bool:
    return b in (0x20, 0x09, 0x0A, 0x0D)


class BpeTokenizer:
    def __init__(self, merges):
        # merges: list of (left_id, right_id) in training order.
        self.merges = [tuple(m) for m in merges]
        self.vocab_size = 256 + len(self.merges)
        self.ranks = {m: i for i, m in enumerate(self.merges)}
        # id -> bytes expansion for decoding
        self.expansions = [bytes([i]) for i in range(256)]
        for (a, b) in self.merges:
            self.expansions.append(self.expansions[a] + self.expansions[b])
        self._piece_cache = {}

    # -- encoding ----------------------------------------------------------
    def _encode_piece(self, piece: bytes):
        cached = self._piece_cache.get(piece)
        if cached is not None:
            return cached
        ids = list(piece)
        while len(ids) >= 2:
            best_rank, best_i = None, None
            for i in range(len(ids) - 1):
                r = self.ranks.get((ids[i], ids[i + 1]))
                if r is not None and (best_rank is None or r < best_rank):
                    best_rank, best_i = r, i
            if best_rank is None:
                break
            ids[best_i:best_i + 2] = [256 + best_rank]
        self._piece_cache[piece] = ids
        return ids

    def encode(self, text: str):
        out = []
        for piece in split_pieces(text.encode("utf-8")):
            out.extend(self._encode_piece(piece))
        return out

    def decode(self, ids) -> str:
        return b"".join(self.expansions[i] for i in ids).decode("utf-8", errors="replace")

    # -- persistence -------------------------------------------------------
    def to_json(self) -> str:
        return json.dumps(
            {
                "type": "byte_bpe",
                "vocab_size": self.vocab_size,
                "merges": [list(m) for m in self.merges],
            }
        )

    @classmethod
    def from_json(cls, text: str) -> "BpeTokenizer":
        obj = json.loads(text)
        assert obj["type"] == "byte_bpe"
        return cls(obj["merges"])


def train_bpe(corpus: str, vocab_size: int) -> BpeTokenizer:
    """Greedy BPE: merge the globally most frequent adjacent pair per round.

    Works on the multiset of distinct pieces, so cost is O(rounds x
    distinct-piece bytes) rather than O(rounds x corpus bytes).
    """
    assert vocab_size > 256
    piece_counts = Counter(split_pieces(corpus.encode("utf-8")))
    pieces = [(list(p), c) for p, c in piece_counts.items()]
    merges = []
    while len(merges) < vocab_size - 256:
        counts = Counter()
        for ids, c in pieces:
            for pair in zip(ids, ids[1:]):
                counts[pair] += c
        if not counts:
            break
        # deterministic: break frequency ties by smaller pair ids
        (a, b), n = min(counts.items(), key=lambda kv: (-kv[1], kv[0]))
        if n < 2:
            break
        new_id = 256 + len(merges)
        merges.append((a, b))
        for ids, _ in pieces:
            i = 0
            while i < len(ids) - 1:
                if ids[i] == a and ids[i + 1] == b:
                    ids[i:i + 2] = [new_id]
                else:
                    i += 1
    return BpeTokenizer(merges)
