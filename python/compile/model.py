"""L2: decoder-only transformer in JAX (RMSNorm + SwiGLU + RoPE).

Three entry points, all pure functions over an explicit parameter list:

  forward_train   (B, T) tokens -> (B, T, V) logits       [training / tables]
  forward_prefill (1, P) padded prompt -> KV cache + next-token id
  forward_spec_step  the paper's verification call: (k, w+1) speculative
                     block vs a *shared* context KV cache -> greedy
                     next-token ids + the block's KV tail.

The speculative step uses the L1 Pallas kernels (kernels/attention.py) for
RMSNorm and the shared-context attention partition; the (w+1)-wide causal
tail partition is dense jnp and merged via flash-partition statistics
(bifurcated attention — see DESIGN.md §Hardware-Adaptation).

Parameters travel as a flat *list* of arrays whose names/shapes are
recorded in the artifact manifest; the rust runtime uploads them once as
PJRT device buffers in the same order.
"""

import jax
import jax.numpy as jnp
import numpy as np

from .configs import ModelConfig
from .kernels import attention as K


# ---------------------------------------------------------------------------
# parameters

def param_spec(cfg: ModelConfig):
    """[(name, shape)] in flat order — the single source of truth shared
    with the manifest and the rust runtime."""
    d, v, hh = cfg.d_model, cfg.vocab_size, cfg.n_heads * cfg.head_dim
    spec = [("tok_emb", (v, d))]
    for i in range(cfg.n_layers):
        p = f"layer{i}."
        spec += [
            (p + "attn_norm", (d,)),
            (p + "wq", (d, hh)),
            (p + "wk", (d, hh)),
            (p + "wv", (d, hh)),
            (p + "wo", (hh, d)),
            (p + "mlp_norm", (d,)),
            (p + "w_gate", (d, cfg.mlp_hidden)),
            (p + "w_up", (d, cfg.mlp_hidden)),
            (p + "w_down", (cfg.mlp_hidden, d)),
        ]
    spec += [("final_norm", (d,)), ("lm_head", (d, v))]
    return spec


def init_params(cfg: ModelConfig, seed: int = 0):
    """He-style init for matrices; norms start at 1; embeddings N(0, 0.02)."""
    rng = np.random.default_rng(seed)
    params = []
    for name, shape in param_spec(cfg):
        if name.endswith("norm"):
            params.append(jnp.ones(shape, jnp.float32))
        elif name == "tok_emb":
            params.append(jnp.asarray(rng.normal(0.0, 0.02, size=shape), jnp.float32))
        else:
            std = (2.0 / shape[0]) ** 0.5
            params.append(jnp.asarray(rng.normal(0.0, std, size=shape), jnp.float32))
    return params


def _unpack(cfg: ModelConfig, params):
    spec = param_spec(cfg)
    assert len(params) == len(spec), (len(params), len(spec))
    d = dict(zip([n for n, _ in spec], params))
    layers = []
    for i in range(cfg.n_layers):
        p = f"layer{i}."
        layers.append({k: d[p + k] for k in
                       ["attn_norm", "wq", "wk", "wv", "wo",
                        "mlp_norm", "w_gate", "w_up", "w_down"]})
    return d["tok_emb"], layers, d["final_norm"], d["lm_head"]


# ---------------------------------------------------------------------------
# building blocks

def rope_cossin(cfg: ModelConfig, positions):
    """positions (...,) -> (cos, sin) each (..., head_dim/2)."""
    hd = cfg.head_dim
    inv = 1.0 / (cfg.rope_theta ** (jnp.arange(0, hd, 2, dtype=jnp.float32) / hd))
    ang = positions.astype(jnp.float32)[..., None] * inv
    return jnp.cos(ang), jnp.sin(ang)


def apply_rope(x, cos, sin):
    """x (..., H, D); cos/sin (..., D/2) broadcast across heads."""
    x1, x2 = jnp.split(x, 2, axis=-1)
    c = cos[..., None, :]
    s = sin[..., None, :]
    return jnp.concatenate([x1 * c - x2 * s, x1 * s + x2 * c], axis=-1)


def _rmsnorm_jnp(x, scale, eps):
    xf = x.astype(jnp.float32)
    ms = jnp.mean(xf * xf, axis=-1, keepdims=True)
    return (xf / jnp.sqrt(ms + eps) * scale).astype(x.dtype)


def _swiglu(lyr, x):
    h = jax.nn.silu(x @ lyr["w_gate"]) * (x @ lyr["w_up"])
    return h @ lyr["w_down"]


# ---------------------------------------------------------------------------
# training / dense forward (plain jnp; used for training + bigram tables)

def forward_train(cfg: ModelConfig, params, tokens):
    """tokens (B, T) int32 -> logits (B, T, V). Full causal attention."""
    tok_emb, layers, final_norm, lm_head = _unpack(cfg, params)
    B, T = tokens.shape
    H, hd = cfg.n_heads, cfg.head_dim
    x = tok_emb[tokens]                                   # (B, T, d)
    pos = jnp.arange(T)
    cos, sin = rope_cossin(cfg, pos)                      # (T, hd/2)
    causal = jnp.tril(jnp.ones((T, T), jnp.bool_))
    scale = 1.0 / jnp.sqrt(jnp.array(hd, jnp.float32))
    for lyr in layers:
        h = _rmsnorm_jnp(x, lyr["attn_norm"], cfg.norm_eps)
        q = apply_rope((h @ lyr["wq"]).reshape(B, T, H, hd), cos, sin)
        k = apply_rope((h @ lyr["wk"]).reshape(B, T, H, hd), cos, sin)
        v = (h @ lyr["wv"]).reshape(B, T, H, hd)
        sc = jnp.einsum("bqhd,bkhd->bhqk", q, k) * scale
        sc = jnp.where(causal[None, None], sc, -jnp.inf)
        p = jax.nn.softmax(sc, axis=-1)
        att = jnp.einsum("bhqk,bkhd->bqhd", p, v).reshape(B, T, H * hd)
        x = x + att @ lyr["wo"]
        h = _rmsnorm_jnp(x, lyr["mlp_norm"], cfg.norm_eps)
        x = x + _swiglu(lyr, h)
    x = _rmsnorm_jnp(x, final_norm, cfg.norm_eps)
    return x @ lm_head


# ---------------------------------------------------------------------------
# prefill: fill the shared KV cache for one prompt

def forward_prefill(cfg: ModelConfig, params, tokens, length):
    """tokens (1, P) int32 padded prompt, length scalar int32 (<= P).

    Returns (next_id () i32, k_cache (layers, max_len, H, hd) f32,
             v_cache (layers, max_len, H, hd) f32).
    Cache positions >= length hold garbage from pad tokens; they are always
    masked by cache_len in subsequent speculative steps.
    """
    tok_emb, layers, final_norm, lm_head = _unpack(cfg, params)
    P = tokens.shape[1]
    H, hd = cfg.n_heads, cfg.head_dim
    x = tok_emb[tokens[0]]                                # (P, d)
    pos = jnp.arange(P)
    cos, sin = rope_cossin(cfg, pos)
    valid = pos < length
    causal = (pos[:, None] >= pos[None, :]) & valid[None, :]
    scale = 1.0 / jnp.sqrt(jnp.array(hd, jnp.float32))
    kc, vc = [], []
    pad = cfg.max_len - P
    for lyr in layers:
        h = _rmsnorm_jnp(x, lyr["attn_norm"], cfg.norm_eps)
        q = apply_rope((h @ lyr["wq"]).reshape(P, H, hd), cos, sin)
        k = apply_rope((h @ lyr["wk"]).reshape(P, H, hd), cos, sin)
        v = (h @ lyr["wv"]).reshape(P, H, hd)
        sc = jnp.einsum("qhd,khd->hqk", q, k) * scale
        sc = jnp.where(causal[None], sc, -jnp.inf)
        p = jax.nn.softmax(sc, axis=-1)
        att = jnp.einsum("hqk,khd->qhd", p, v).reshape(P, H * hd)
        x = x + att @ lyr["wo"]
        h = _rmsnorm_jnp(x, lyr["mlp_norm"], cfg.norm_eps)
        x = x + _swiglu(lyr, h)
        kc.append(jnp.pad(k, ((0, pad), (0, 0), (0, 0))))
        vc.append(jnp.pad(v, ((0, pad), (0, 0), (0, 0))))
    x = _rmsnorm_jnp(x, final_norm, cfg.norm_eps)
    logits = x @ lm_head                                  # (P, V)
    next_id = jnp.argmax(logits[length - 1], axis=-1).astype(jnp.int32)
    return next_id, jnp.stack(kc), jnp.stack(vc)


# ---------------------------------------------------------------------------
# the verification step (the paper's hot path)

def forward_spec_step(cfg: ModelConfig, params, tokens, k_cache, v_cache,
                      cache_len, *, interpret=True, use_pallas=True):
    """Verify a (k, w+1) speculative block against the shared context cache.

    tokens:   (k, w1) int32 — column 0 is the last accepted token (repeated
              across rows), columns 1..w are the drafts.
    k_cache:  (layers, max_len, H, hd) f32 — shared context keys.
    v_cache:  (layers, max_len, H, hd) f32.
    cache_len: scalar int32 — number of valid cache positions (the block's
              first token sits at absolute position cache_len).

    Returns:
      next_ids (k, w1) int32 — greedy argmax after each block position,
      k_tail   (layers, k, w1, H, hd) f32 — keys of the block tokens,
      v_tail   (layers, k, w1, H, hd) f32.
    """
    tok_emb, layers, final_norm, lm_head = _unpack(cfg, params)
    kk, w1 = tokens.shape
    H, hd = cfg.n_heads, cfg.head_dim
    x = tok_emb[tokens]                                   # (k, w1, d)
    pos = cache_len + jnp.arange(w1)                      # (w1,)
    cos, sin = rope_cossin(cfg, pos)                      # (w1, hd/2)
    scale = 1.0 / jnp.sqrt(jnp.array(hd, jnp.float32))
    causal = jnp.arange(w1)[:, None] >= jnp.arange(w1)[None, :]
    k_tails, v_tails = [], []
    for li, lyr in enumerate(layers):
        if use_pallas:
            h = K.rmsnorm(x, lyr["attn_norm"], cfg.norm_eps, interpret=interpret)
        else:
            h = _rmsnorm_jnp(x, lyr["attn_norm"], cfg.norm_eps)
        q = apply_rope((h @ lyr["wq"]).reshape(kk, w1, H, hd), cos, sin)
        kt = apply_rope((h @ lyr["wk"]).reshape(kk, w1, H, hd), cos, sin)
        vt = (h @ lyr["wv"]).reshape(kk, w1, H, hd)
        k_tails.append(kt)
        v_tails.append(vt)

        # --- context partition: ONE shared-cache attention for all k rows
        qf = q.reshape(kk * w1, H, hd)
        if use_pallas:
            o_ctx, m_ctx, l_ctx = K.ctx_attention(
                qf, k_cache[li], v_cache[li], cache_len, interpret=interpret)
        else:
            from .kernels.ref import ctx_attention_ref
            o_ctx, m_ctx, l_ctx = ctx_attention_ref(
                qf, k_cache[li], v_cache[li], cache_len)
        o_ctx = o_ctx.reshape(kk, w1, H, hd)
        m_ctx = m_ctx.reshape(kk, w1, H)
        l_ctx = l_ctx.reshape(kk, w1, H)

        # --- tail partition: tiny (w1 x w1) causal attention per row
        sc = jnp.einsum("bqhd,bkhd->bqhk", q, kt) * scale   # (k, w1, H, w1)
        sc = jnp.where(causal[None, :, None, :], sc, -jnp.inf)
        m_tail = jnp.max(sc, axis=-1)                       # (k, w1, H)
        p = jnp.exp(sc - m_tail[..., None])
        p = jnp.where(causal[None, :, None, :], p, 0.0)
        l_tail = jnp.sum(p, axis=-1)
        o_tail = jnp.einsum("bqhk,bkhd->bqhd", p, vt)

        att = K.merge_partitions(o_ctx, m_ctx, l_ctx, o_tail, m_tail, l_tail)
        x = x + att.reshape(kk, w1, H * hd).astype(x.dtype) @ lyr["wo"]
        if use_pallas:
            h = K.rmsnorm(x, lyr["mlp_norm"], cfg.norm_eps, interpret=interpret)
        else:
            h = _rmsnorm_jnp(x, lyr["mlp_norm"], cfg.norm_eps)
        x = x + _swiglu(lyr, h)

    x = _rmsnorm_jnp(x, final_norm, cfg.norm_eps)
    logits = x @ lm_head                                  # (k, w1, V)
    next_ids = jnp.argmax(logits, axis=-1).astype(jnp.int32)
    return next_ids, jnp.stack(k_tails), jnp.stack(v_tails)


def kv_commit(cfg: ModelConfig, k_cache, v_cache, k_tail, v_tail, row, length):
    """Device-side cache commit (perf path — see EXPERIMENTS.md §Perf-L3).

    Writes `k_tail[:, row]` / `v_tail[:, row]` (the accepted speculation
    row's KV, all w+1 positions) into the shared cache starting at
    `length`. Positions beyond the accepted count hold stale values but are
    always masked by cache_len in subsequent steps, so writing the full
    w+1 window unconditionally is safe and keeps the op static-shaped.

    k_cache/v_cache: (layers, max_len, H, hd); k_tail/v_tail:
    (layers, k, w1, H, hd); row, length: scalars.
    """
    kt = jax.lax.dynamic_index_in_dim(k_tail, row, axis=1, keepdims=False)
    vt = jax.lax.dynamic_index_in_dim(v_tail, row, axis=1, keepdims=False)
    zero = jnp.zeros((), jnp.int32)
    kc = jax.lax.dynamic_update_slice(k_cache, kt, (zero, length, zero, zero))
    vc = jax.lax.dynamic_update_slice(v_cache, vt, (zero, length, zero, zero))
    return kc, vc


def loss_fn(cfg: ModelConfig, params, tokens):
    """Next-token cross entropy; tokens (B, T)."""
    logits = forward_train(cfg, params, tokens[:, :-1])
    targets = tokens[:, 1:]
    logp = jax.nn.log_softmax(logits, axis=-1)
    ll = jnp.take_along_axis(logp, targets[..., None], axis=-1)[..., 0]
    return -jnp.mean(ll)
