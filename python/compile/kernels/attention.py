"""L1 Pallas kernels: batched speculative-verification attention.

The paper's verification hot-spot is one forward call on a (k, w+1) block
whose rows all share the same context. The naive implementation (paper §4.1)
`repeat`s the context KV k times; here the context partition is computed
*once* against a single shared cache — the "bifurcated attention" the paper
cites as the fix for its batching overhead (Athiwaratkun et al. 2024) —
and only the tiny (w+1)-wide speculative tail is per-row.

TPU adaptation (DESIGN.md §Hardware-Adaptation): the context KV streams
HBM→VMEM in `BLOCK_L`-sized tiles via BlockSpec; all k·(w+1) query rows live
in VMEM and are reused against every tile (flash-style online softmax, MXU
matmul shapes (R, D) x (D, BLOCK_L)). Always `interpret=True`: the CPU PJRT
client cannot execute Mosaic custom-calls.
"""

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

# Context tile length. 128 keeps the (R, BLOCK_L) score tile MXU-shaped on
# a real TPU and the VMEM footprint small; see EXPERIMENTS.md §Perf-L1.
BLOCK_L = 128

NEG_INF = -1e30


def _ctx_attn_kernel(q_ref, k_ref, v_ref, len_ref,
                     out_ref, m_ref, l_ref,
                     acc_ref, mm_ref, ll_ref, *, block_l, scale):
    """Grid (H, L // block_l): one head x one context tile per step.

    q_ref:  (R, D)        queries of this head (all k·(w+1) rows)
    k_ref:  (block_l, D)  context key tile of this head
    v_ref:  (block_l, D)  context value tile
    len_ref: (1, 1)       valid context length (SMEM scalar)
    out/m/l: unnormalized flash partials of the context partition
    acc/mm/ll: VMEM scratch accumulators carried across the tile loop
    """
    t = pl.program_id(1)
    nt = pl.num_programs(1)

    @pl.when(t == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)
        mm_ref[...] = jnp.full_like(mm_ref, NEG_INF)
        ll_ref[...] = jnp.zeros_like(ll_ref)

    ctx_len = len_ref[0, 0]
    q = q_ref[...].astype(jnp.float32)          # (R, D)
    k = k_ref[...].astype(jnp.float32)          # (block_l, D)
    v = v_ref[...].astype(jnp.float32)
    s = jax.lax.dot_general(q, k, (((1,), (1,)), ((), ())),
                            preferred_element_type=jnp.float32) * scale  # (R, block_l)
    pos = t * block_l + jax.lax.broadcasted_iota(jnp.int32, s.shape, 1)
    s = jnp.where(pos < ctx_len, s, NEG_INF)

    m_prev = mm_ref[...]                        # (R, 1)
    m_cur = jnp.max(s, axis=-1, keepdims=True)
    m_new = jnp.maximum(m_prev, m_cur)
    # clamp: fully-masked-so-far rows keep exp() finite
    p = jnp.exp(s - jnp.maximum(m_new, NEG_INF / 2))
    p = jnp.where(pos < ctx_len, p, 0.0)
    alpha = jnp.exp(jnp.minimum(m_prev - m_new, 0.0))
    alpha = jnp.where(m_prev <= NEG_INF / 2, 0.0, alpha)
    ll_ref[...] = ll_ref[...] * alpha + jnp.sum(p, axis=-1, keepdims=True)
    acc_ref[...] = acc_ref[...] * alpha + jax.lax.dot_general(
        p, v, (((1,), (0,)), ((), ())), preferred_element_type=jnp.float32)
    mm_ref[...] = m_new

    @pl.when(t == nt - 1)
    def _fin():
        out_ref[...] = acc_ref[...]
        m_fin = mm_ref[...]
        m_ref[...] = jnp.where(m_fin <= NEG_INF / 2, 0.0, m_fin)
        l_ref[...] = ll_ref[...]


def ctx_attention(q, k_ctx, v_ctx, ctx_len, *, block_l=BLOCK_L, interpret=True):
    """Flash attention of (R, H, D) queries against the shared (L, H, D) cache.

    Returns unnormalized partials (out (R, H, D) f32, m (R, H) f32,
    l (R, H) f32) matching `ref.ctx_attention_ref`.
    """
    R, H, D = q.shape
    L = k_ctx.shape[0]
    assert L % block_l == 0, (L, block_l)
    scale = 1.0 / (D ** 0.5)
    # head-major layouts so BlockSpec tiles are contiguous per head
    qh = jnp.transpose(q, (1, 0, 2))            # (H, R, D)
    kh = jnp.transpose(k_ctx, (1, 0, 2))        # (H, L, D)
    vh = jnp.transpose(v_ctx, (1, 0, 2))
    len_arr = jnp.reshape(ctx_len.astype(jnp.int32), (1, 1))

    grid = (H, L // block_l)
    out, m, l = pl.pallas_call(
        functools.partial(_ctx_attn_kernel, block_l=block_l, scale=scale),
        grid=grid,
        in_specs=[
            pl.BlockSpec((None, R, D), lambda h, t: (h, 0, 0)),
            pl.BlockSpec((None, block_l, D), lambda h, t: (h, t, 0)),
            pl.BlockSpec((None, block_l, D), lambda h, t: (h, t, 0)),
            pl.BlockSpec((1, 1), lambda h, t: (0, 0)),
        ],
        out_specs=[
            pl.BlockSpec((None, R, D), lambda h, t: (h, 0, 0)),
            pl.BlockSpec((None, R, 1), lambda h, t: (h, 0, 0)),
            pl.BlockSpec((None, R, 1), lambda h, t: (h, 0, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((H, R, D), jnp.float32),
            jax.ShapeDtypeStruct((H, R, 1), jnp.float32),
            jax.ShapeDtypeStruct((H, R, 1), jnp.float32),
        ],
        scratch_shapes=[
            pltpu.VMEM((R, D), jnp.float32),
            pltpu.VMEM((R, 1), jnp.float32),
            pltpu.VMEM((R, 1), jnp.float32),
        ],
        interpret=interpret,
    )(qh, kh, vh, len_arr)
    return (jnp.transpose(out, (1, 0, 2)),
            jnp.transpose(m[..., 0]),
            jnp.transpose(l[..., 0]))


def _rmsnorm_kernel(x_ref, s_ref, o_ref, *, eps):
    x = x_ref[...].astype(jnp.float32)
    ms = jnp.mean(x * x, axis=-1, keepdims=True)
    o_ref[...] = (x / jnp.sqrt(ms + eps) * s_ref[...].astype(jnp.float32)).astype(o_ref.dtype)


def rmsnorm(x, scale, eps=1e-5, *, interpret=True):
    """Pallas RMSNorm over the last axis; x (..., D), scale (D,)."""
    orig_shape = x.shape
    D = orig_shape[-1]
    x2 = x.reshape(-1, D)
    out = pl.pallas_call(
        functools.partial(_rmsnorm_kernel, eps=eps),
        out_shape=jax.ShapeDtypeStruct(x2.shape, x.dtype),
        interpret=interpret,
    )(x2, scale)
    return out.reshape(orig_shape)


def merge_partitions(out_ctx, m_ctx, l_ctx, out_tail, m_tail, l_tail):
    """Merge two flash partitions (unnormalized acc, max, normalizer).

    All inputs broadcast over leading dims; m/l have a trailing singleton
    against out's feature axis handled by the caller.
    """
    m = jnp.maximum(m_ctx, m_tail)
    a_ctx = jnp.exp(m_ctx - m)
    a_tail = jnp.exp(m_tail - m)
    l = l_ctx * a_ctx + l_tail * a_tail
    out = out_ctx * a_ctx[..., None] + out_tail * a_tail[..., None]
    return out / jnp.maximum(l, 1e-30)[..., None]
