"""Pure-jnp oracles for the Pallas kernels (correctness ground truth).

Every kernel in this package has a dense, obviously-correct counterpart
here; pytest + hypothesis assert allclose across shapes/dtypes.
"""

import jax.numpy as jnp


def rmsnorm_ref(x, scale, eps=1e-5):
    """RMSNorm over the last axis (float32 accumulation)."""
    xf = x.astype(jnp.float32)
    ms = jnp.mean(xf * xf, axis=-1, keepdims=True)
    return (xf / jnp.sqrt(ms + eps) * scale.astype(jnp.float32)).astype(x.dtype)


def ctx_attention_ref(q, k_ctx, v_ctx, ctx_len):
    """Dense attention of queries against the shared context cache.

    q:      (R, H, D)   flattened query rows (R = k * (w+1))
    k_ctx:  (L, H, D)   shared context keys (max_len L, valid first ctx_len)
    v_ctx:  (L, H, D)
    ctx_len: scalar int — number of valid cache positions.

    Returns (out (R, H, D), m (R, H), l (R, H)): the *unnormalized* flash
    partials of the context partition — out = sum_j exp(s_j - m) v_j,
    m = row max score, l = softmax normalizer. These merge with the
    speculative-tail partition in the model (bifurcated attention).
    """
    R, H, D = q.shape
    L = k_ctx.shape[0]
    scale = 1.0 / jnp.sqrt(jnp.array(D, jnp.float32))
    qf = q.astype(jnp.float32)
    scores = jnp.einsum("rhd,lhd->hrl", qf, k_ctx.astype(jnp.float32)) * scale
    mask = jnp.arange(L)[None, None, :] < ctx_len
    scores = jnp.where(mask, scores, -jnp.inf)
    m = jnp.max(scores, axis=-1)                      # (H, R)
    m_safe = jnp.where(jnp.isfinite(m), m, 0.0)       # guard ctx_len == 0
    p = jnp.where(mask, jnp.exp(scores - m_safe[..., None]), 0.0)
    l = jnp.sum(p, axis=-1)                           # (H, R)
    out = jnp.einsum("hrl,lhd->rhd", p, v_ctx.astype(jnp.float32))
    return out, jnp.transpose(m_safe), jnp.transpose(l)


def spec_attention_ref(q, k_ctx, v_ctx, ctx_len, k_tail, v_tail):
    """Full speculative-verification attention (the end-to-end oracle).

    q:       (B, W1, H, D)  queries for B speculation rows, W1 = w+1 tokens
    k_ctx:   (L, H, D)      shared context keys (valid first ctx_len)
    v_ctx:   (L, H, D)
    k_tail:  (B, W1, H, D)  per-row keys of the speculative tokens
    v_tail:  (B, W1, H, D)

    Row b, position i attends to: context[:ctx_len] ++ tail[b, :i+1] (causal).
    Returns (B, W1, H, D).
    """
    B, W1, H, D = q.shape
    L = k_ctx.shape[0]
    scale = 1.0 / jnp.sqrt(jnp.array(D, jnp.float32))
    qf = q.astype(jnp.float32)
    sc_ctx = jnp.einsum("bwhd,lhd->bhwl", qf, k_ctx.astype(jnp.float32)) * scale
    ctx_mask = jnp.arange(L)[None, None, None, :] < ctx_len
    sc_ctx = jnp.where(ctx_mask, sc_ctx, -jnp.inf)
    sc_tail = jnp.einsum("bwhd,bxhd->bhwx", qf, k_tail.astype(jnp.float32)) * scale
    causal = jnp.arange(W1)[:, None] >= jnp.arange(W1)[None, :]
    sc_tail = jnp.where(causal[None, None, :, :], sc_tail, -jnp.inf)
    scores = jnp.concatenate([sc_ctx, sc_tail], axis=-1)  # (B,H,W1,L+W1)
    m = jnp.max(scores, axis=-1, keepdims=True)
    p = jnp.exp(scores - m)
    p = p / jnp.sum(p, axis=-1, keepdims=True)
    out_ctx = jnp.einsum("bhwl,lhd->bwhd", p[..., :L], v_ctx.astype(jnp.float32))
    out_tail = jnp.einsum("bhwx,bxhd->bwhd", p[..., L:], v_tail.astype(jnp.float32))
    return (out_ctx + out_tail).astype(q.dtype)
