"""Model + artifact-grid configuration shared by the whole compile path.

Three "nano" decoder-only transformers stand in for the paper's
Phi-3 (3B) / Mistral-7B / Vicuna-13B — see DESIGN.md §Substitutions.
All sizes are chosen so the full artifact build (train + lower) completes
on a single CPU core in a few minutes.
"""

from dataclasses import dataclass, field, asdict


@dataclass(frozen=True)
class ModelConfig:
    name: str
    vocab_size: int = 512
    d_model: int = 128
    n_layers: int = 3
    n_heads: int = 4
    head_dim: int = 32
    mlp_ratio: float = 8.0 / 3.0  # SwiGLU hidden = ratio * d_model (rounded to 8)
    max_len: int = 512
    rope_theta: float = 10000.0
    norm_eps: float = 1e-5
    # The paper analog this config stands in for (documentation only).
    analog: str = ""

    @property
    def mlp_hidden(self) -> int:
        h = int(self.d_model * self.mlp_ratio)
        return ((h + 7) // 8) * 8

    def n_params(self) -> int:
        d, v, hd, nh = self.d_model, self.vocab_size, self.head_dim, self.n_heads
        per_layer = (
            3 * d * (nh * hd)  # wq, wk, wv
            + (nh * hd) * d    # wo
            + 3 * d * self.mlp_hidden  # w_gate, w_up (d->h) and w_down (h->d)
            + 2 * d            # two rmsnorm scales
        )
        return v * d + self.n_layers * per_layer + d + d * v  # emb + layers + final norm + lm head


# Paper-analog model zoo. `small`≈Phi-3 row, `base`≈Mistral-7B row,
# `large`≈Vicuna-13B row of Table 1.
MODELS = {
    "small": ModelConfig(name="small", d_model=96, n_layers=2, n_heads=3,
                         head_dim=32, analog="Phi-3-mini (3B)"),
    "base": ModelConfig(name="base", d_model=128, n_layers=3, n_heads=4,
                        head_dim=32, analog="Mistral-7B-Instruct"),
    "large": ModelConfig(name="large", d_model=192, n_layers=4, n_heads=6,
                         head_dim=32, analog="Vicuna-13B"),
}

# ---------------------------------------------------------------------------
# AOT shape grid. Each (k, w) pair gets its own HLO executable; rust picks
# the right one from the manifest. Union of everything the benches need:
#   - (1, 0): plain greedy decoding baseline
#   - Fig. 2: k sweep at w in {1, 2, 3}
#   - Table 1 / Figs 3, 5-9 grid: k in {1,5,10,20,25} x w in {2,4,...,14}
#   - serving default (10, 10)
FIG2_KS = [1, 2, 5, 10, 15, 20, 25]
FIG2_WS = [1, 2, 3]
GRID_KS = [1, 5, 10, 20, 25]
GRID_WS = [2, 4, 6, 8, 10, 12, 14]
PREFILL_BUCKETS = [64, 128, 256]


def step_shapes():
    """All (k, w) verify-step shapes to lower, deduplicated and sorted."""
    shapes = {(1, 0)}
    for k in FIG2_KS:
        for w in FIG2_WS:
            shapes.add((k, w))
    for k in GRID_KS:
        for w in GRID_WS:
            shapes.add((k, w))
    return sorted(shapes)


# N-gram table sizes (see ngram_tables.py).
BIGRAM_TOPK = 32
UNIGRAM_TOPK = 64
EXT_BIGRAM_W = 16  # greedy bigram-chain depth stored per (token, rank)


def manifest_model_entry(cfg: ModelConfig) -> dict:
    d = asdict(cfg)
    d["mlp_hidden"] = cfg.mlp_hidden
    d["n_params"] = cfg.n_params()
    return d
